from repro.data.tokens import TokenStream
from repro.data.ehr import choa_like, movielens_like

__all__ = ["TokenStream", "choa_like", "movielens_like"]
