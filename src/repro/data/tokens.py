"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step) — a counter-based generator — so
the iterator state is a single integer. Checkpoint/restart and elastic
re-sharding never replay or skip data: resuming at step N reproduces exactly
the batch any worker count would have seen. Per-host sharding slices the
global batch by data-parallel rank.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    batch: int            # global batch
    seq_len: int
    seed: int = 0
    step: int = 0         # iterator state (checkpointable)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for `step` (counter-based; no stream state)."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        # zipf-ish marginal over vocab, with short repeated motifs so tiny
        # models can actually learn structure in examples/tests
        base = rng.zipf(1.3, size=(self.batch, self.seq_len)).astype(np.int64)
        tokens = (base % (self.vocab_size - 1)) + 1
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1
        return {"tokens": tokens.astype(np.int32), "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            out = self.batch_at(self.step)
            self.step += 1
            yield out

    def state(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: Dict[str, int]) -> "TokenStream":
        self.seed = int(state["seed"])
        self.step = int(state["step"])
        return self
