"""Synthetic EHR-shaped irregular tensors (CHOA-like geometry, paper §5.1).

The real CHOA dataset is K=464,900 subjects x J=1,328 features x <=166 weekly
observations, 12.3M nonzeros; MovieLens is K=25,249 x J=26,096 x <=19 years,
8.9M nonzeros. These generators reproduce the *geometry* (row/column sparsity
distributions) at any scale factor so CPU benchmarks stress the same access
patterns the paper's experiments did.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.coo import IrregularCOO, SubjectCOO

__all__ = ["choa_like", "movielens_like"]


def _build(K, J, max_rows, mean_rows, feats_per_obs, seed, phenotypes=None):
    """Per-subject generation with BATCHED numpy draws.

    The per-observation work — one Poisson count, one without-replacement
    feature pick, and the value draws per observation — is vectorized over
    all I_k observations of a subject (3 rng calls per subject instead of
    ~3*I_k): counts come from one batched Poisson; the without-replacement
    picks take the first n_i entries of an argsorted random-key matrix (a
    uniform random permutation per observation, so marginally identical to
    per-row ``rng.choice(..., replace=False)``); values from one batched
    Poisson over the total pick count. Output is deterministic per seed (the
    stream differs from the pre-vectorization per-observation loop; the
    geometry statistics are asserted unchanged in tests/test_ehr.py).
    """
    rng = np.random.default_rng(seed)
    subs = []
    R = 0 if phenotypes is None else phenotypes.shape[1]
    if phenotypes is None:
        # long-tail feature popularity (zipf), like diagnostic code frequency
        pop = 1.0 / np.arange(1, J + 1) ** 0.8
        pop /= pop.sum()
    for k in range(K):
        I_k = int(np.clip(rng.poisson(mean_rows) + 1, 1, max_rows))
        if phenotypes is None:
            active = rng.choice(J, size=min(J, max(3, int(rng.poisson(feats_per_obs * 3)))),
                                replace=False, p=pop)
        else:
            r_k = rng.integers(0, R)
            w = phenotypes[:, r_k]
            active = np.argsort(-w)[: max(3, feats_per_obs * 2)]
        A = active.size
        n = np.minimum(np.maximum(rng.poisson(feats_per_obs, I_k), 1), A)
        # first n_i of a random permutation per row == uniform sample
        # without replacement per observation
        order = np.argsort(rng.random((I_k, A)), axis=1)
        picked = np.arange(A)[None, :] < n[:, None]          # [I_k, A] mask
        cols = active[order[picked]]                          # row-major flat
        rows = np.repeat(np.arange(I_k), n)
        vals = rng.poisson(2.0, rows.size) + 1.0
        key = rows.astype(np.int64) * J + cols.astype(np.int64)
        uk, inv = np.unique(key, return_inverse=True)
        v = np.zeros(uk.size)
        np.add.at(v, inv, vals.astype(np.float64))
        subs.append(SubjectCOO(
            rows=(uk // J).astype(np.int32),
            cols=(uk % J).astype(np.int32),
            vals=v, n_rows=I_k, n_cols=J))
    return IrregularCOO(subjects=subs, n_cols=J)


def choa_like(*, scale: float = 0.01, seed: int = 0,
              with_phenotypes: bool = False, rank: int = 5):
    """CHOA-shaped EHR data at `scale` of the real K (full: 464,900)."""
    K = max(8, int(464_900 * scale))
    J = 1_328
    phen = None
    if with_phenotypes:
        rng = np.random.default_rng(seed + 1)
        phen = rng.random((J, rank)) ** 4    # sparse-ish phenotype defs
    return _build(K, J, max_rows=166, mean_rows=28, feats_per_obs=4,
                  seed=seed, phenotypes=phen)


def movielens_like(*, scale: float = 0.05, seed: int = 0):
    """MovieLens-shaped: many variables (movies), few observations (years)."""
    K = max(8, int(25_249 * scale))
    J = 26_096
    return _build(K, J, max_rows=19, mean_rows=6, feats_per_obs=20, seed=seed)
