"""Fault tolerance for long-running ALS / training loops.

Three pieces, all host-side (nothing here enters jitted code):

* :class:`FaultInjector` — deterministic transient-fault injection for
  exercising the recovery paths in tests and the ``--fail-at`` flag of
  ``launch/train.py``.
* :func:`run_with_retries` — retry a step function on
  :class:`TransientFault`; the caller escalates to checkpoint-restore when
  retries are exhausted (see ``launch/train.py``).
* :class:`StepWatchdog` — flags straggler steps whose wall time exceeds a
  multiple of the running median (slow host, contended interconnect, ...).
"""
from __future__ import annotations

import statistics
from typing import Callable, Iterable, List, Optional

__all__ = ["TransientFault", "FaultInjector", "StepWatchdog", "run_with_retries"]


class TransientFault(RuntimeError):
    """A failure expected to succeed on retry (preempted host, flaky link)."""


class FaultInjector:
    """Raise :class:`TransientFault` on each listed step's first `times`
    attempts.

    ``times=1`` (default) models a transient blip: the in-place retry
    succeeds. ``times > max_retries`` exhausts :func:`run_with_retries`,
    forcing callers through the checkpoint-restore + rewind path — and the
    fault then clears, so the re-run after restore proceeds (a fault that
    never clears would just loop restore forever, which no FT scheme fixes).
    """

    def __init__(self, fail_steps: Iterable[int] = (), *, times: int = 1):
        self.fail_steps = frozenset(fail_steps)
        self.times = times
        self._fired: dict = {}

    def check(self, step: int) -> None:
        if step in self.fail_steps and self._fired.get(step, 0) < self.times:
            self._fired[step] = self._fired.get(step, 0) + 1
            raise TransientFault(f"injected fault at step {step}")


def run_with_retries(fn: Callable, *args, max_retries: int = 3,
                     on_retry: Optional[Callable] = None):
    """Call ``fn(*args)``, retrying up to `max_retries` times on
    :class:`TransientFault`. `on_retry(attempt, exc)` runs before each retry;
    the last fault re-raises once retries are exhausted."""
    for attempt in range(max_retries + 1):
        try:
            return fn(*args)
        except TransientFault as e:
            if attempt >= max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)


class StepWatchdog:
    """Flag steps slower than ``factor`` x the running median step time.

    Flagged durations are excluded from the history so one straggler does not
    drag the baseline up; ``min_history`` observations are required before
    anything is flagged (cold-start compiles are never stragglers).
    """

    def __init__(self, factor: float = 3.0, *, min_history: int = 3,
                 window: int = 50):
        self.factor = factor
        self.min_history = min_history
        self.window = window
        self._times: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record one step duration; returns True if `step` is a straggler."""
        hist = self._times[-self.window:]
        slow = (len(hist) >= self.min_history
                and dt > self.factor * statistics.median(hist))
        if slow:
            self.flagged.append(step)
        else:
            self._times.append(dt)
            del self._times[:-self.window]   # bound history for long runs
        return slow
