"""Fault tolerance for long-running ALS / training loops.

Three pieces, all host-side (nothing here enters jitted code):

* :class:`FaultInjector` — deterministic transient-fault injection for
  exercising the recovery paths in tests, the ``--fail-at`` flag of
  ``launch/train.py``, and the chunk-boundary fault surface of
  ``launch/decompose.py`` (``repro.dist.supervisor``). Besides transient
  faults it can *poison* a step — the supervisor corrupts the carried state
  with NaNs so the numerical-health sentinel's rollback path is exercisable.
* :func:`run_with_retries` — retry a step function on
  :class:`TransientFault` with optional exponential backoff + deterministic
  jitter; the caller escalates to checkpoint-restore when retries are
  exhausted (see ``launch/train.py`` / ``repro.dist.supervisor``).
* :class:`StepWatchdog` — flags straggler steps whose wall time exceeds a
  multiple of the running median (slow host, contended interconnect, ...).
"""
from __future__ import annotations

import random
import statistics
import time
from typing import Callable, Iterable, List, Mapping, Optional, Union

__all__ = ["TransientFault", "FaultInjector", "StepWatchdog", "run_with_retries"]


class TransientFault(RuntimeError):
    """A failure expected to succeed on retry (preempted host, flaky link)."""


def _per_step_counts(steps: Union[Mapping[int, int], Iterable[int]],
                     default: int) -> dict:
    """Normalize ``steps`` to {step: times}: a mapping passes through, a bare
    iterable gets `default` firings per listed step."""
    if isinstance(steps, Mapping):
        return {int(s): int(t) for s, t in steps.items()}
    return {int(s): default for s in steps}


class FaultInjector:
    """Deterministic fault injection at step/chunk boundaries.

    ``fail_steps`` lists steps whose :meth:`check` raises
    :class:`TransientFault` on the first `times` attempts. ``times=1``
    (default) models a transient blip: the in-place retry succeeds.
    ``times > max_retries`` exhausts :func:`run_with_retries`, forcing
    callers through the checkpoint-restore + rewind path — and the fault then
    clears, so the re-run after restore proceeds (a fault that never clears
    would just loop restore forever, which no FT scheme fixes). Either
    argument also accepts a ``{step: times}`` mapping for per-step counts
    (one command line can mix a blip at chunk 1 with an exhausting fault at
    chunk 3 — see ``launch/decompose.py --fail-at``).

    ``nan_steps`` lists steps to *poison*: :meth:`poison` returns True on
    each listed step's first `times` calls, and the caller corrupts its
    carried state (NaN factors) before dispatching — the supervisor's
    numerical-health sentinel then detects the non-finite fit and rolls back
    to the last good checkpoint.
    """

    def __init__(self, fail_steps: Union[Mapping[int, int], Iterable[int]] = (),
                 *, times: int = 1,
                 nan_steps: Union[Mapping[int, int], Iterable[int]] = ()):
        self._fail_times = _per_step_counts(fail_steps, times)
        self._nan_times = _per_step_counts(nan_steps, 1)
        self.fail_steps = frozenset(self._fail_times)
        self.nan_steps = frozenset(self._nan_times)
        self.times = times
        self._fired: dict = {}
        self._poisoned: dict = {}

    def check(self, step: int) -> None:
        if self._fired.get(step, 0) < self._fail_times.get(step, 0):
            self._fired[step] = self._fired.get(step, 0) + 1
            raise TransientFault(f"injected fault at step {step}")

    def poison(self, step: int) -> bool:
        """True on each listed step's first `times` calls; the caller NaNs
        its state in response (the injector itself never touches arrays)."""
        if self._poisoned.get(step, 0) < self._nan_times.get(step, 0):
            self._poisoned[step] = self._poisoned.get(step, 0) + 1
            return True
        return False


def run_with_retries(fn: Callable, *args, max_retries: int = 3,
                     on_retry: Optional[Callable] = None,
                     backoff: float = 0.0, backoff_factor: float = 2.0,
                     jitter: float = 0.0, seed: int = 0,
                     sleep: Callable = time.sleep, **kwargs):
    """Call ``fn(*args, **kwargs)``, retrying up to `max_retries` times on
    :class:`TransientFault`; the last fault re-raises once retries are
    exhausted. `on_retry(attempt, exc)` runs before each retry.

    ``backoff > 0`` sleeps ``backoff * backoff_factor**attempt`` seconds
    before retry `attempt` (exponential), scaled by ``1 + jitter * u`` with
    ``u ~ U[0, 1)`` drawn from a PRIVATE ``random.Random(seed)`` stream —
    deterministic and seedable, so tests (and bitwise replay comparisons)
    see identical schedules without touching the global RNG. `sleep` is
    injectable for tests.
    """
    rng = random.Random(seed) if jitter > 0.0 else None
    for attempt in range(max_retries + 1):
        try:
            return fn(*args, **kwargs)
        except TransientFault as e:
            if attempt >= max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff > 0.0:
                delay = backoff * (backoff_factor ** attempt)
                if rng is not None:
                    delay *= 1.0 + jitter * rng.random()
                sleep(delay)


class StepWatchdog:
    """Flag steps slower than ``factor`` x the running median step time.

    Flagged durations are excluded from the history so one straggler does not
    drag the baseline up; ``min_history`` observations are required before
    anything is flagged (cold-start compiles are never stragglers).
    """

    def __init__(self, factor: float = 3.0, *, min_history: int = 3,
                 window: int = 50):
        self.factor = factor
        self.min_history = min_history
        self.window = window
        self._times: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Record one step duration; returns True if `step` is a straggler."""
        hist = self._times[-self.window:]
        slow = (len(hist) >= self.min_history
                and dt > self.factor * statistics.median(hist))
        if slow:
            self.flagged.append(step)
        else:
            self._times.append(dt)
            del self._times[:-self.window]   # bound history for long runs
        return slow
