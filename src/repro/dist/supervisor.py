"""Fault-tolerant supervisor for the chunked device-resident ALS fit.

``repro.core.engine.fit_device`` dispatches one compiled chunk of
``opts.check_every`` iterations per host sync. For a multi-hour pod-scale
fit that loop is fragile: a preempted host or flaky interconnect kills the
dispatch, a straggling device stretches it, and a numerical blow-up (bad
conditioning, aggressive precision) silently fills the trajectory with NaNs.
:func:`supervised_fit` runs the SAME chunk loop — same chunk lengths, same
tol semantics, bitwise identical history and factors on a faultless run
under the scan engine — with a recovery ladder wrapped around every chunk
boundary:

1. **retry** — the chunk dispatch runs under
   :func:`repro.dist.fault.run_with_retries` (exponential backoff +
   deterministic jitter); a :class:`repro.dist.fault.TransientFault` is
   retried in place up to ``max_retries`` times.
2. **restore** — exhausted retries escalate to elastic checkpoint-restore:
   the newest ``checkpoint/ckpt.py`` checkpoint (written every
   ``ckpt_every`` chunks; globally-unsharded arrays, so a write-on-N
   restores on M devices) is loaded, the fit history rewound to its step,
   and the chunks replayed. Replay is bitwise: the scan chunk closes over
   the data, so the carried ``Parafac2State`` is the only state.
3. **rollback** — a numerical-health sentinel checks every chunk's fit
   values on the host sync: non-finite fits, or a fit regression below the
   best seen (ALS fit is monotone), roll the state back to the last good
   chunk boundary and replay. After ``health_retries`` consecutive failed
   replays the retry tightens regularization
   (``Parafac2Options.ridge = ridge_escalation``, growing 10x per further
   escalation) — the classic remedy for an ill-conditioned Gram — and a run
   that still cannot produce finite fits raises.

A :class:`repro.dist.fault.StepWatchdog` observes every successful chunk's
wall time; straggler flags are reported (``SupervisorReport.stragglers``)
but never consume the retry budget — slow is not broken. Fault injection at
chunk boundaries goes through :class:`repro.dist.fault.FaultInjector`
(``--fail-at`` / ``--nan-at`` on ``launch/decompose.py``).

Resume: with ``ckpt_dir`` set, checkpoints carry the fit history in their
``extra`` blob (step = iterations completed); ``resume=True`` picks up the
newest one and continues — restore-then-continue is bitwise the
uninterrupted run (the ``tests/test_ckpt.py`` contract).

See docs/ARCHITECTURE.md (stage 11) for the full decision tree.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.dist.fault import (FaultInjector, StepWatchdog, TransientFault,
                              run_with_retries)

__all__ = ["SupervisorConfig", "SupervisorReport", "supervised_fit"]


@dataclasses.dataclass
class SupervisorConfig:
    """Knobs for :func:`supervised_fit` (all host-side)."""

    # --- retry ladder -----------------------------------------------------
    max_retries: int = 3            # in-place retries per chunk dispatch
    backoff: float = 0.0            # base backoff seconds (0 = no sleep)
    backoff_factor: float = 2.0     # exponential growth per attempt
    jitter: float = 0.0             # deterministic jitter fraction (seeded)
    retry_seed: int = 0             # seed for the jitter stream
    # --- checkpointing ----------------------------------------------------
    ckpt_dir: Optional[str] = None  # None = in-memory snapshots only
    ckpt_every: int = 1             # write a checkpoint every N chunks
    keep: int = 3                   # checkpoints retained on disk
    resume: bool = False            # continue from ckpt_dir's newest step
    # --- sentinels --------------------------------------------------------
    watchdog_factor: float = 3.0    # straggler threshold vs running median
    regress_tol: float = 1e-3       # fit drop below best-seen => unhealthy
    health_retries: int = 1         # clean replays before ridge escalation
    ridge_escalation: float = 1e-6  # first escalated ridge (10x per repeat)
    max_escalations: int = 3        # give up (raise) past this many
    # --- fault injection / test seams ------------------------------------
    injector: Optional[FaultInjector] = None
    sleep: Callable = time.sleep            # injectable for backoff tests
    clock: Callable = time.perf_counter     # injectable for watchdog tests
    # compiled-chunk cache shared ACROSS supervised_fit calls (a {length:
    # callable} dict the caller owns). Lengths already present are treated as
    # warm. This is how repeated fits of one geometry — warm restarts, the
    # benchmark's overhead measurement — skip recompiling the chunk.
    chunk_cache: Optional[Dict[int, Callable]] = None


@dataclasses.dataclass
class SupervisorReport:
    """What happened on the way to convergence — stamped into the
    ``launch/summary.py`` payload by ``launch/decompose.py``."""

    retries: int = 0                # in-place transient-fault retries
    restores: int = 0               # exhausted-retry checkpoint restores
    rollbacks: int = 0              # health-sentinel rollbacks
    stragglers: List[int] = dataclasses.field(default_factory=list)
    checkpoints_written: int = 0
    resumed_from_step: Optional[int] = None
    ridge_final: float = 0.0        # >0 iff regularization was escalated
    escalations: int = 0
    chunks: int = 0                 # successful (committed) chunk dispatches

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _poison(state):
    """NaN the H factor: every downstream update and the fit inherit the
    NaN, which is exactly what the health sentinel must catch."""
    import jax.numpy as jnp
    return state._replace(H=state.H * jnp.asarray(float("nan"), state.H.dtype))


def _healthy(fits: np.ndarray, best: float, regress_tol: float) -> bool:
    if not np.all(np.isfinite(fits)):
        return False
    # ALS fit is monotone: a drop below the best fit seen (beyond tol) means
    # the trajectory diverged even if every value is finite
    return not (np.isfinite(best) and float(fits.min()) < best - regress_tol)


def supervised_fit(
    data,
    opts,
    *,
    max_iters: int = 100,
    tol: float = 1e-6,
    seed: int = 0,
    verbose: bool = False,
    state=None,
    config: Optional[SupervisorConfig] = None,
) -> Tuple[Any, List[float], SupervisorReport]:
    """Fault-tolerant drop-in for ``fit`` on the chunked scan/mesh engines.

    Same ``(state, history)`` contract as :func:`repro.core.parafac2.fit`
    plus a :class:`SupervisorReport`; a faultless supervised run is BITWISE
    the bare ``fit`` under ``engine="scan"`` (identical chunk lengths and tol
    semantics, donation off so a failed dispatch's input carry survives
    retry) and ≤1e-8 under ``engine="mesh"``.
    """
    # lazy: repro.core imports repro.dist.sharding at module scope, so the
    # engine import must not run at repro.dist import time
    from repro.core import engine as _engine
    from repro.core import parafac2 as p2
    from repro import checkpoint as ckpt

    cfg = config or SupervisorConfig()
    if opts.engine not in ("scan", "mesh"):
        raise ValueError(
            f"supervised_fit wraps the chunked device engines "
            f"(engine='scan'|'mesh'), got engine={opts.engine!r}")
    if opts.check_every <= 0:
        raise ValueError(
            "supervised_fit needs chunked execution (check_every > 0); the "
            "while_loop variant has no chunk boundaries to supervise")
    if opts.compress not in ("", "none"):
        raise ValueError(
            f"supervised_fit runs the core ALS only (compress={opts.compress!r})")
    if cfg.ckpt_every < 1:
        raise ValueError(f"ckpt_every must be >= 1, got {cfg.ckpt_every}")

    if state is None:
        state = p2.init_state(data, opts, seed)
    history: List[float] = []
    report = SupervisorReport()

    if cfg.resume:
        if cfg.ckpt_dir is None:
            raise ValueError("resume=True needs ckpt_dir")
        step = ckpt.latest_step(cfg.ckpt_dir)
        if step is not None:
            state, step, extra = ckpt.restore(cfg.ckpt_dir, state, step=step)
            history = [float(f) for f in extra.get("history", [])][:step]
            report.resumed_from_step = step
            if verbose:
                print(f"[supervisor] resumed from step {step} "
                      f"(fit={history[-1] if history else float('nan'):.6f})")

    run_opts = opts
    chunks: Dict[int, Callable] = (
        cfg.chunk_cache if cfg.chunk_cache is not None else {})
    warm_lengths: set = set(chunks)  # chunk lengths whose compile already ran
    watchdog = StepWatchdog(factor=cfg.watchdog_factor)
    injector = cfg.injector

    # last good chunk boundary (in-memory; arrays are immutable, refs suffice)
    good_state, good_history = state, list(history)
    # newest on-disk step, so the restore path knows whether disk can help
    disk_step = (ckpt.latest_step(cfg.ckpt_dir)
                 if cfg.ckpt_dir is not None else None)

    def save(st, hist):
        nonlocal disk_step
        if cfg.ckpt_dir is None:
            return
        ckpt.save(cfg.ckpt_dir, len(hist), st,
                  extra={"history": hist}, keep=cfg.keep)
        disk_step = len(hist)
        report.checkpoints_written += 1

    def best_fit(hist):
        return max(hist) if hist else float("-inf")

    def on_retry(attempt, exc):
        report.retries += 1
        if verbose:
            print(f"[supervisor] retry {attempt + 1}/{cfg.max_retries} "
                  f"after {exc}")

    chunk_idx = len(history) // opts.check_every   # resumes keep chunk ids
    consecutive_bad = 0
    prev = history[-1] if history else -np.inf
    done = False
    while len(history) < max_iters and not done:
        n = min(opts.check_every, max_iters - len(history))
        if n not in chunks:
            # donate=False: a retried dispatch must be able to re-read its
            # input carry (and the benchmark's ≤5% overhead gate holds the
            # cost of forgoing donation accountable)
            chunks[n] = _engine.make_als_chunk(data, run_opts, n, donate=False)

        dispatch_state = state
        if injector is not None and injector.poison(chunk_idx):
            if verbose:
                print(f"[supervisor] injected NaN poison at chunk {chunk_idx}")
            dispatch_state = _poison(dispatch_state)

        timing = {}

        def attempt_chunk(s):
            if injector is not None:
                injector.check(chunk_idx)
            t0 = cfg.clock()
            s2, fits = chunks[n](s)
            fits = np.asarray(fits)        # the chunk's one device sync
            timing["dt"] = cfg.clock() - t0
            return s2, fits

        try:
            new_state, fits = run_with_retries(
                attempt_chunk, dispatch_state,
                max_retries=cfg.max_retries, on_retry=on_retry,
                backoff=cfg.backoff, backoff_factor=cfg.backoff_factor,
                jitter=cfg.jitter, seed=cfg.retry_seed, sleep=cfg.sleep)
        except TransientFault as e:
            # retry budget exhausted: elastic checkpoint-restore + rewind.
            # Disk is authoritative when present (the preemption story —
            # write-on-N-resume-on-M); the in-memory boundary covers
            # ckpt_dir=None and the pre-first-checkpoint window.
            report.restores += 1
            if cfg.ckpt_dir is not None and disk_step is not None:
                state, step, extra = ckpt.restore(
                    cfg.ckpt_dir, state, step=disk_step)
                history = [float(f) for f in extra.get("history", [])][:step]
            else:
                state, history = good_state, list(good_history)
            good_state, good_history = state, list(history)
            prev = history[-1] if history else -np.inf
            chunk_idx = len(history) // opts.check_every
            consecutive_bad = 0
            if verbose:
                print(f"[supervisor] retries exhausted ({e}); restored to "
                      f"step {len(history)}, replaying")
            continue

        if not _healthy(fits, best_fit(history), cfg.regress_tol):
            # numerical-health sentinel: roll back to the last good chunk
            # boundary; repeated failures of the SAME replay escalate to a
            # tightened-regularization retry (ridge on every Gram)
            report.rollbacks += 1
            consecutive_bad += 1
            state, history = good_state, list(good_history)
            prev = history[-1] if history else -np.inf
            chunk_idx = len(history) // opts.check_every
            if consecutive_bad > cfg.health_retries:
                report.escalations += 1
                if report.escalations > cfg.max_escalations:
                    raise RuntimeError(
                        f"supervised_fit: fit stayed non-finite/regressing "
                        f"after {report.escalations - 1} regularization "
                        f"escalations (last ridge={run_opts.ridge:g})")
                new_ridge = cfg.ridge_escalation * (
                    10.0 ** (report.escalations - 1))
                run_opts = dataclasses.replace(opts, ridge=new_ridge)
                report.ridge_final = new_ridge
                chunks = {}          # recompile against the ridged step
                warm_lengths = set() # ...whose compile dispatches are slow
                if verbose:
                    print(f"[supervisor] escalating: ridge={new_ridge:g}")
            if verbose:
                print(f"[supervisor] unhealthy chunk {chunk_idx} "
                      f"(finite={bool(np.all(np.isfinite(fits)))}); rolled "
                      f"back to step {len(history)}")
            continue

        # ---- healthy chunk: commit ---------------------------------------
        consecutive_bad = 0
        state = new_state
        if n in warm_lengths:
            # a compile dispatch (any chunk length's first call) is slow by
            # construction, not a straggler — never observed, so it neither
            # flags nor drags the watchdog's median up
            if watchdog.observe(chunk_idx, timing.get("dt", 0.0)):
                report.stragglers.append(chunk_idx)
                if verbose:
                    print(f"[supervisor] straggler flag on chunk {chunk_idx} "
                          f"({timing['dt']:.3f}s)")
        else:
            warm_lengths.add(n)
        for f in fits:
            history.append(float(f))
            if len(history) > 1 and abs(f - prev) < tol:
                done = True                # fit_device's exact semantics:
            prev = f                       # keep the full chunk
        good_state, good_history = state, list(history)
        report.chunks += 1
        chunk_idx += 1
        if report.chunks % cfg.ckpt_every == 0:
            save(state, history)
        if verbose:
            print(f"[supervisor:{opts.engine}] iter {len(history) - 1:3d}  "
                  f"fit={history[-1]:.6f}")

    if cfg.ckpt_dir is not None and disk_step != len(history):
        save(state, history)               # final boundary, resume-exact
    return state, history, report
