"""repro.dist — the distribution subsystem (sharding rules + fault tolerance).

Two modules:

* :mod:`repro.dist.sharding` — logical-axis sharding rules (``LM_RULES`` /
  ``SP_RULES``), the ``axis_rules`` context stack, the :func:`shard`
  constraint helper used throughout :mod:`repro.models`, path-based parameter
  sharding (:func:`param_shardings`), and the scan-unrolling switch used by
  the dry-run's roofline probes.
* :mod:`repro.dist.fault` — fault injection, transient-fault retries, and a
  straggler watchdog for resilient long ALS / training runs.
* :mod:`repro.dist.supervisor` — the fault-tolerant supervisor wrapping the
  chunked ALS engines (retry -> checkpoint-restore -> health rollback).
  Imported lazily below: it pulls in :mod:`repro.core.engine`, while
  :mod:`repro.core` imports this package at module scope.

The SPARTan story (see ``docs/ARCHITECTURE.md``): subjects shard subject-wide
over EVERY mesh axis (the decomposition has no tensor-parallel dimension, so
"model" would otherwise idle), every per-bucket MTTKRP partial result is a
plain add over the subject axis, and under ``pjit`` those adds lower to
all-reduces — the paper's "sum partial results in parallel".
"""
from repro.dist.sharding import (
    barrier,
    LM_RULES,
    SP_RULES,
    axis_rules,
    current_mesh,
    current_rules,
    enforce_divisible,
    logical_spec,
    param_shardings,
    param_spec,
    psum_subjects,
    shard,
    subject_collectives,
    subject_mesh_axes,
    unroll_active,
    unroll_loops,
)
from repro.dist.fault import (
    FaultInjector,
    StepWatchdog,
    TransientFault,
    run_with_retries,
)

__all__ = [
    "LM_RULES",
    "SP_RULES",
    "axis_rules",
    "current_mesh",
    "current_rules",
    "enforce_divisible",
    "logical_spec",
    "param_shardings",
    "param_spec",
    "barrier",
    "psum_subjects",
    "shard",
    "subject_collectives",
    "subject_mesh_axes",
    "unroll_active",
    "unroll_loops",
    "FaultInjector",
    "StepWatchdog",
    "TransientFault",
    "run_with_retries",
    "SupervisorConfig",
    "SupervisorReport",
    "supervised_fit",
]

_LAZY = {"SupervisorConfig", "SupervisorReport", "supervised_fit"}


def __getattr__(name):
    if name in _LAZY:
        from repro.dist import supervisor as _sup

        return getattr(_sup, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
