"""Named-axis sharding rules and the logical->mesh resolution machinery.

Model code annotates arrays with LOGICAL axis names (``"batch"``, ``"heads"``,
``"subjects"``, ...) via :func:`shard`; a rule table maps each logical name to
zero or more PHYSICAL mesh axes (``"pod"``, ``"data"``, ``"model"``). The
mapping is installed with the :func:`axis_rules` context manager, so the same
model code lowers unsharded on one CPU device (tests), data-parallel on a
small host-device mesh, or fully sharded on a production pod — with no code
changes, only a different ``(rules, mesh)`` pair.

Rule tables
-----------
``LM_RULES`` is the standard megatron-style layout: batch-like axes over the
data-parallel axes ``("pod", "data")``, head/ffn/vocab/expert axes over
``"model"`` (tensor/expert parallelism), residual stream replicated over
``"model"``. ``SP_RULES`` additionally shards the residual-stream sequence
axis ``"seq_res"`` over ``"model"`` (sequence parallelism: norms and
elementwise work also parallelize over ``"model"``, at the cost of
all-gathers at each block boundary).

The ``"subjects"`` axis is the PARAFAC2 workload: SPARTan's per-subject
partial MTTKRP results are plain adds over this axis, so constraining it onto
the mesh makes the bucket reductions lower to all-reduces (the paper's "sum
partial results in parallel"); :mod:`repro.core.backend` applies the
constraint uniformly around the MTTKRP math. It maps to EVERY
mesh axis — the decomposition has no tensor-parallel dimension, so leaving
``"model"`` idle would waste its memory and compute (subject-wide sharding;
see ``launch/dryrun.py::parafac2_shardings``).

Parameter sharding is PATH-based, not shape-based: :func:`param_spec` matches
the pytree path of each leaf ("attn/wq", "mlp/w_down", "embed/tokens", ...)
and returns a :class:`~jax.sharding.PartitionSpec` that puts the contraction
or output dimension on ``"model"`` and the complementary dimension on the
fsdp axis ``"data"`` (ZeRO-style: optimizer state flattens through the same
paths, so it partitions identically for free — see ``optim/adamw.py``).

Every resolved spec passes through :func:`enforce_divisible`, which silently
replicates any dimension a mesh axis does not divide evenly — annotations are
best-effort hints, never hard failures.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LM_RULES",
    "SP_RULES",
    "axis_rules",
    "current_mesh",
    "current_rules",
    "enforce_divisible",
    "logical_spec",
    "param_shardings",
    "param_spec",
    "barrier",
    "psum_subjects",
    "shard",
    "subject_collectives",
    "subject_mesh_axes",
    "unroll_active",
    "unroll_loops",
]

# One rule table entry: logical axis name -> mesh axis name(s) or None.
Rules = Dict[str, Union[str, Tuple[str, ...], None]]

_DP = ("pod", "data")   # data-parallel mesh axes (pod absent on 1-pod meshes)

LM_RULES: Rules = {
    # batch-like axes: data-parallel
    "batch": _DP,
    "tokens": _DP,          # flattened [B*S(*k)] token axes (moe dispatch)
    # PARAFAC2 subjects: subject-wide — over every axis incl. "model"
    "subjects": ("pod", "data", "model"),
    # residual stream: replicated over "model" (megatron TP)
    "seq": None,
    "seq_res": None,
    "embed": None,
    # tensor-parallel axes
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    # expert-parallel axes
    "experts": "model",
    "expert_cap": "model",  # flattened [E*capacity] dispatch buffers
}

# Sequence-parallel variant: the residual stream's seq axis also shards over
# "model" between blocks (attention/mlp still gather seq internally).
SP_RULES: Rules = {**LM_RULES, "seq_res": "model"}


# ---------------------------------------------------------------------------
# context stack: (rules, mesh) pairs + the scan-unrolling switch
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.stack = []     # [(rules, mesh), ...]
        self.unroll = 0
        self.collective = []  # [axis_names, ...] — inside shard_map bodies


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(rules: Rules, mesh: Optional[Mesh] = None):
    """Install a (rules, mesh) pair for :func:`shard` / :func:`logical_spec`."""
    _CTX.stack.append((rules, mesh))
    try:
        yield
    finally:
        _CTX.stack.pop()


def current_rules() -> Optional[Rules]:
    return _CTX.stack[-1][0] if _CTX.stack else None


def current_mesh() -> Optional[Mesh]:
    return _CTX.stack[-1][1] if _CTX.stack else None


@contextlib.contextmanager
def unroll_loops():
    """Unroll `lax.scan` layer/kv-block loops while active.

    XLA cost analysis counts a while-loop body ONCE regardless of trip count,
    so the dry-run's roofline probes lower fully unrolled models; training
    and tests keep the compact scanned HLO.
    """
    _CTX.unroll += 1
    try:
        yield
    finally:
        _CTX.unroll -= 1


def unroll_active() -> bool:
    return _CTX.unroll > 0


# ---------------------------------------------------------------------------
# manual-collective mode (shard_map bodies)
# ---------------------------------------------------------------------------

def subject_mesh_axes(mesh: Mesh, rules: Optional[Rules] = None) -> Tuple[str, ...]:
    """Mesh axes the "subjects" logical axis resolves to on `mesh` (the axes a
    shard_map over subjects maps manually, and psums reduce over)."""
    rules = rules if rules is not None else (current_rules() or LM_RULES)
    entry = rules.get("subjects")
    if entry is None:
        return ()
    names = entry if isinstance(entry, tuple) else (entry,)
    return tuple(n for n in names if n in mesh.axis_names)


@contextlib.contextmanager
def subject_collectives(axis_names: Sequence[str]):
    """Mark the enclosed trace as a shard_map body manually mapped over the
    subjects axis: :func:`psum_subjects` becomes ``lax.psum`` over
    `axis_names`, and :func:`shard` constraints become no-ops (inside
    shard_map the mesh axes are already manual — ``with_sharding_constraint``
    over them is meaningless). The mesh execution engine
    (:mod:`repro.core.engine`) enters this around the scanned ALS step.
    """
    _CTX.collective.append(tuple(axis_names))
    _CTX.stack.append((None, None))   # suppress shard() inside the body
    try:
        yield
    finally:
        _CTX.stack.pop()
        _CTX.collective.pop()


def psum_subjects(x: jax.Array) -> jax.Array:
    """Cross-subject reduction hook: identity under pjit/GSPMD (sharding
    constraints make XLA insert the all-reduces), an explicit
    ``lax.psum`` over the subjects mesh axes inside a
    :func:`subject_collectives` (shard_map) body. The ALS step calls this on
    every value produced by a reduction over the subject axis (MTTKRP partial
    sums, W grams, fit residual terms)."""
    if not _CTX.collective:
        return x
    axes = _CTX.collective[-1]
    if not axes:
        return x
    return jax.lax.psum(x, axes)


# ---------------------------------------------------------------------------
# logical -> physical resolution
# ---------------------------------------------------------------------------

def _mesh_axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    n = 1
    for nm in names:
        if nm in mesh.axis_names:
            n *= mesh.devices.shape[mesh.axis_names.index(nm)]
    return n


def _resolve_entry(entry, mesh: Optional[Mesh]):
    """Rule value -> PartitionSpec entry: filter missing mesh axes, collapse
    1-tuples to bare names, empty to None."""
    if entry is None:
        return None
    names = entry if isinstance(entry, tuple) else (entry,)
    if mesh is not None:
        names = tuple(n for n in names if n in mesh.axis_names)
    if not names:
        return None
    return names if len(names) > 1 else names[0]


def logical_spec(axes: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None) -> P:
    """Resolve logical axis names to a PartitionSpec under the current rules.

    Unknown names and names with no surviving mesh axis resolve to None
    (replicated); with no rules installed the spec is empty (fully
    replicated).
    """
    rules = current_rules()
    if rules is None:
        return P()
    mesh = mesh if mesh is not None else current_mesh()
    return P(*[_resolve_entry(rules.get(ax), mesh) if ax is not None else None
               for ax in axes])


def enforce_divisible(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Replicate every spec dimension whose mesh-axis product does not divide
    the array dimension evenly (constraints are hints, not requirements)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        size = _mesh_axis_size(mesh, names)
        out.append(entry if size <= 1 or dim % size == 0 else None)
    return P(*out)


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain `x`'s sharding by logical axis names; no-op outside a mesh.

    `axes` is one logical name (or None) per array dimension. Under an active
    ``axis_rules(rules, mesh)`` context this lowers to
    ``with_sharding_constraint``; anywhere else (unit tests, single-device
    examples) it returns `x` unchanged.
    """
    mesh = current_mesh()
    if mesh is None or current_rules() is None:
        return x
    spec = enforce_divisible(logical_spec(axes, mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@jax.custom_vjp
def barrier(x: jax.Array) -> jax.Array:
    """Differentiable `lax.optimization_barrier`: pins value order against XLA
    hoisting (e.g. keeping a bf16 cast on the producer side of a dispatch
    all-gather) and, unlike the raw primitive, has a VJP — the cotangent is
    barriered the same way."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


barrier.defvjp(_barrier_fwd, _barrier_bwd)


# ---------------------------------------------------------------------------
# path-based parameter sharding
# ---------------------------------------------------------------------------

# weights contracted on their LAST dim at apply time: output dim on "model"
# (column-parallel), input dim on the fsdp axis.
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "w_gate", "w_up",
    "in_proj_z", "in_proj_x", "in_proj_B", "in_proj_C", "in_proj_dt",
    "w_in", "w_gate_branch", "wa", "wx",
    "lm_head", "patch_proj",
})
# weights whose FIRST dim is the model-sharded activation dim (row-parallel):
# input dim on "model", output dim on the fsdp axis.
_ROW_PARALLEL = frozenset({"wo", "w_down", "out_proj", "w_out"})


def param_spec(path: str, ndim: int, stacked: bool = False) -> P:
    """PartitionSpec for a parameter (or optimizer-moment) pytree leaf.

    `path` is the "/"-joined pytree path; optimizer prefixes ("m/...",
    "v/...") pass through because every rule matches on path suffixes.
    `stacked` marks scan-stacked group params (leading layer dim, never
    sharded); the remaining dims follow the unstacked rule.
    """
    lead: Tuple[Optional[str], ...] = (None,) if stacked else ()
    body = ndim - len(lead)
    leaf = path.rsplit("/", 1)[-1]
    if body <= 1:
        return P()          # scalars, biases, norm scales: replicated
    if "experts/" in path:
        # MoE expert stacks [E, d, f]: expert dim on "model" (EP), matching
        # the manual shard_map path's in_specs (models/moe.py).
        return P(*lead, "model", *([None] * (body - 1)))
    if "conv/" in path:
        # depthwise conv [W, C]: channel dim follows the activation layout
        return P(*lead, *([None] * (body - 1)), "model")
    if "embed/tokens" in path:
        # token embedding [V, d]: vocab on "model" (sharded-vocab CE), d fsdp
        return P(*lead, "model", *([None] * (body - 2)), "data")
    if leaf in _ROW_PARALLEL:
        return P(*lead, "model", *([None] * (body - 2)), "data")
    if leaf in _COL_PARALLEL:
        return P(*lead, "data", *([None] * (body - 2)), "model")
    return P()              # unknown (router gates, ...): replicated


def _key_str(entry: Any) -> str:
    """One pytree KeyEntry -> path segment (DictKey/GetAttrKey/SequenceKey)."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def param_shardings(tree: Any, mesh: Mesh) -> Any:
    """NamedShardings for a param/opt-state pytree (of arrays or
    ShapeDtypeStructs) via :func:`param_spec` on each leaf's path."""

    def visit(path, leaf):
        pathstr = "/".join(_key_str(p) for p in path)
        stacked = "groups/" in pathstr
        ndim = len(getattr(leaf, "shape", ()) or ())
        spec = param_spec(pathstr, ndim, stacked=stacked)
        spec = P(*[_resolve_entry(e, mesh) for e in spec])
        spec = enforce_divisible(spec, leaf.shape, mesh) if ndim else spec
        entries = list(spec)
        while entries and entries[-1] is None:   # P(None, None) == P()
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(visit, tree)
