"""Least-squares solvers backing the constraint registry's direct routes.

``hals_nnls`` solves  min_{X >= 0} || T - X G^T ||_F  given the MTTKRP
M = T G and the Gram matrix A = G^T G, via HALS (hierarchical ALS) column
sweeps — the standard scalable replacement for the active-set NNLS of Bro &
de Jong used by the paper's MATLAB implementation. Matmul + elementwise only
-> TPU-friendly. ``ridge_solve`` is the unconstrained update.

These are the ``"hals"`` (spec ``nonneg``) and ``"ridge"`` (spec ``none``)
solver routes of :mod:`repro.core.constraints`; factor updates reach them
through the registry (``Constraint.update``), not directly. The AO-ADMM
route (``nonneg_admm`` / ``l1`` / ``smooth`` / compositions) lives in
``constraints.admm_solve``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["hals_nnls", "ridge_solve"]


def hals_nnls(M: jax.Array, A: jax.Array, X0: jax.Array, *, sweeps: int = 5,
              eps: float = 1e-12) -> jax.Array:
    """HALS sweeps for min_{X>=0} ||T - X G^T||, normal form X A = M.

    M:  [N, R] MTTKRP result
    A:  [R, R] Gram (Hadamard of factor Grams)
    X0: [N, R] warm start (the previous factor — ALS warm starts are exact here)
    """
    R = A.shape[0]
    diag = jnp.maximum(jnp.diag(A), eps)

    def sweep(X, _):
        def col(r, X):
            # residual correlation for column r with X fixed elsewhere
            numer = M[:, r] - X @ A[:, r] + X[:, r] * A[r, r]
            xr = jnp.maximum(numer / diag[r], 0.0)
            return X.at[:, r].set(xr)

        X = jax.lax.fori_loop(0, R, col, X)
        return X, None

    X, _ = jax.lax.scan(sweep, jnp.maximum(X0, 0.0), None, length=sweeps)
    return X


def ridge_solve(M: jax.Array, A: jax.Array, *, ridge: float = 1e-10) -> jax.Array:
    """Unconstrained ALS update  X = M A^+  via a ridge-stabilized solve.

    The ridge amount is floored at a dtype-aware smallest-normal scale so a
    fully collapsed factor (A == 0, e.g. after an aggressive l1 sweep zeroed
    its companion) yields X == 0 instead of NaN; the floor is inactive
    (bitwise identity) for any non-degenerate Gram.
    """
    R = A.shape[0]
    floor = jnp.asarray(jnp.finfo(A.dtype).tiny, A.dtype) * 128
    lam = jnp.maximum(ridge * jnp.trace(A) / R, floor)
    A_reg = A + lam * jnp.eye(R, dtype=A.dtype)
    return jax.scipy.linalg.solve(A_reg, M.T, assume_a="pos").T
