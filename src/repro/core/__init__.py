"""Paper core: PARAFAC2 + SPARTan MTTKRP on bucketed compressed-column data."""
from repro.core.irregular import (
    Bucket, Bucketed, BlockBucket, SparseBucket, bucketize, bucket_format,
    cc_bucket_like, to_block_bucket, FORMATS, LANE)
from repro.core.backend import MttkrpBackend, get_backend
from repro.core.compress import (
    CompressedBucket,
    CompressedData,
    Preprocess,
    available as available_preprocess,
    parse_preprocess_spec,
    preprocess_summary,
    register_preprocess,
)
from repro.core.constraints import (
    Constraint,
    available as available_constraints,
    parse_constraint_arg,
    parse_spec as parse_constraint_spec,
)
from repro.core.parafac2 import (
    Parafac2Options,
    Parafac2State,
    als_step,
    constraints_for,
    fit,
    init_state,
    reconstruct_uk,
    update_subjects,
    w_global,
)
from repro.core.engine import (
    ENGINES, fit_device, make_als_chunk, make_als_while, make_subject_update)

__all__ = [
    "CompressedBucket",
    "CompressedData",
    "Preprocess",
    "available_preprocess",
    "parse_preprocess_spec",
    "preprocess_summary",
    "register_preprocess",
    "cc_bucket_like",
    "Constraint",
    "available_constraints",
    "constraints_for",
    "parse_constraint_arg",
    "parse_constraint_spec",
    "ENGINES",
    "fit_device",
    "make_als_chunk",
    "make_als_while",
    "Bucket",
    "Bucketed",
    "BlockBucket",
    "SparseBucket",
    "bucketize",
    "bucket_format",
    "to_block_bucket",
    "FORMATS",
    "LANE",
    "MttkrpBackend",
    "get_backend",
    "Parafac2Options",
    "Parafac2State",
    "als_step",
    "fit",
    "init_state",
    "make_subject_update",
    "reconstruct_uk",
    "update_subjects",
    "w_global",
]
