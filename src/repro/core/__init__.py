"""Paper core: PARAFAC2 + SPARTan MTTKRP on bucketed compressed-column data."""
from repro.core.irregular import Bucket, Bucketed, BlockBucket, bucketize, to_block_bucket, LANE
from repro.core.backend import MttkrpBackend, get_backend
from repro.core.parafac2 import (
    Parafac2Options,
    Parafac2State,
    als_step,
    fit,
    init_state,
    reconstruct_uk,
)

__all__ = [
    "Bucket",
    "Bucketed",
    "BlockBucket",
    "bucketize",
    "to_block_bucket",
    "LANE",
    "MttkrpBackend",
    "get_backend",
    "Parafac2Options",
    "Parafac2State",
    "als_step",
    "fit",
    "init_state",
    "reconstruct_uk",
]
