"""Model interpretation helpers — the paper's Section 5.3 workflow.

* V columns      -> phenotype definitions (feature memberships)
* diag(S_k)=W[k] -> per-subject phenotype importance (sortable)
* U_k columns    -> per-subject temporal signatures (evolution over I_k steps)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["top_phenotype_features", "subject_top_phenotypes",
           "temporal_signature", "model_is_nonneg"]


def top_phenotype_features(
    V: np.ndarray, feature_names: Optional[Sequence[str]] = None, top: int = 10
) -> List[List[Tuple[str, float]]]:
    """For each phenotype r, the top features by weight in V(:, r)."""
    V = np.asarray(V)
    J, R = V.shape
    names = list(feature_names) if feature_names is not None else [f"feat_{j}" for j in range(J)]
    out = []
    for r in range(R):
        col = V[:, r]
        idx = np.argsort(-col)[:top]
        out.append([(names[j], float(col[j])) for j in idx if col[j] > 0])
    return out


def subject_top_phenotypes(W: np.ndarray, k: int, top: int = 2) -> List[Tuple[int, float]]:
    """Most relevant phenotypes for subject k by importance diag(S_k) = W[k,:]."""
    w = np.asarray(W)[k]
    idx = np.argsort(-w)[:top]
    return [(int(r), float(w[r])) for r in idx]


def model_is_nonneg(constraints) -> bool:
    """Whether a fitted model's V and W factors are guaranteed nonnegative.

    ``constraints`` may be a ``Parafac2Options``, a per-mode spec mapping
    ({"v": "nonneg+l1:0.1", ...}), or None (unknown — treated as the paper's
    nonnegative default).
    """
    if constraints is None:
        return True
    from repro.core.constraints import parse_spec

    if hasattr(constraints, "constraint_specs"):   # Parafac2Options
        constraints = constraints.constraint_specs()
    return all(parse_spec(constraints.get(m, "none")).nonneg
               for m in ("v", "w"))


def temporal_signature(
    Uk: np.ndarray,
    phenotypes: Sequence[int],
    clip_nonneg: Optional[bool] = None,
    *,
    constraints=None,
) -> Dict[int, np.ndarray]:
    """Temporal evolution of selected phenotypes for one subject.

    Per the paper: only non-negative elements of the signature are
    interpreted — but ONLY when the model was actually fit under
    nonnegativity (X_k, S_k, V all nonneg). ``clip_nonneg=None`` (default)
    consults the fitted constraint spec: pass the ``Parafac2Options`` the
    model was fit with (or its per-mode spec dict) as ``constraints``.
    Signatures from an unconstrained or l1-only fit are returned unclipped —
    silently zeroing their negative lobes would fabricate structure. Pass an
    explicit ``clip_nonneg`` bool to override.
    """
    if clip_nonneg is None:
        clip_nonneg = model_is_nonneg(constraints)
    Uk = np.asarray(Uk)
    out = {}
    for r in phenotypes:
        sig = Uk[:, r]
        out[int(r)] = np.maximum(sig, 0.0) if clip_nonneg else sig
    return out
