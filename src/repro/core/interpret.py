"""Model interpretation helpers — the paper's Section 5.3 workflow.

* V columns      -> phenotype definitions (feature memberships)
* diag(S_k)=W[k] -> per-subject phenotype importance (sortable)
* U_k columns    -> per-subject temporal signatures (evolution over I_k steps)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["top_phenotype_features", "subject_top_phenotypes", "temporal_signature"]


def top_phenotype_features(
    V: np.ndarray, feature_names: Optional[Sequence[str]] = None, top: int = 10
) -> List[List[Tuple[str, float]]]:
    """For each phenotype r, the top features by weight in V(:, r)."""
    V = np.asarray(V)
    J, R = V.shape
    names = list(feature_names) if feature_names is not None else [f"feat_{j}" for j in range(J)]
    out = []
    for r in range(R):
        col = V[:, r]
        idx = np.argsort(-col)[:top]
        out.append([(names[j], float(col[j])) for j in idx if col[j] > 0])
    return out


def subject_top_phenotypes(W: np.ndarray, k: int, top: int = 2) -> List[Tuple[int, float]]:
    """Most relevant phenotypes for subject k by importance diag(S_k) = W[k,:]."""
    w = np.asarray(W)[k]
    idx = np.argsort(-w)[:top]
    return [(int(r), float(w[r])) for r in idx]


def temporal_signature(
    Uk: np.ndarray, phenotypes: Sequence[int], clip_nonneg: bool = True
) -> Dict[int, np.ndarray]:
    """Temporal evolution of selected phenotypes for one subject.

    Per the paper: only non-negative elements of the signature are interpreted
    (X_k, S_k, V are all non-negative under the constrained model).
    """
    Uk = np.asarray(Uk)
    out = {}
    for r in phenotypes:
        sig = Uk[:, r]
        out[int(r)] = np.maximum(sig, 0.0) if clip_nonneg else sig
    return out
