"""Device-resident ALS execution engines: scan chunks, while_loop, shard_map.

The reference fitting loop (``core/parafac2.py::fit`` with ``engine="host"``)
dispatches one jitted ``als_step`` per iteration and forces a device sync
every iteration via ``float(state.fit)`` — at small ranks the host loop, not
the MTTKRP math, is the wall-clock floor. This module runs the same algebra
as compiled device-resident programs:

``engine="scan"``
    ``lax.scan`` over fixed chunks of ``opts.check_every`` iterations per
    dispatch. The ``Parafac2State`` carry is donated back to the runtime
    (no per-iteration realloc), the per-iteration fit history is accumulated
    on device as the scan's ys, and the host only syncs ONCE per chunk to run
    the tol check on the chunk's fit values. Convergence is therefore
    detected at chunk granularity: up to ``check_every - 1`` extra
    iterations may run past the tol crossing (harmless — ALS fit is
    monotone), and ``history[-1]`` always equals the returned state's fit.

``opts.check_every = 0`` (while_loop variant)
    The whole run is ONE dispatch: ``lax.while_loop`` with the tol check
    evaluated on device, reproducing the host loop's stopping rule exactly
    (stop after the first iteration whose fit change is below tol). The fit
    history lands in a preallocated ``[max_iters]`` device buffer that the
    host truncates once, after the loop returns.

``engine="mesh"``
    The scanned (or while'd) step additionally wrapped in ``shard_map`` over
    the subjects bucket axis: every ``Bucket`` leaf and every bucketed-W
    shard splits over the mesh axes the ``"subjects"`` rule resolves to
    (:func:`repro.dist.sharding.subject_mesh_axes`), H/V/global-W/fit stay
    replicated, and the cross-subject reductions inside ``als_step`` go
    through :func:`repro.dist.sharding.psum_subjects`, which lowers to
    explicit ``lax.psum`` over those axes inside the body (and is the
    identity everywhere else). This is where the PR-1 mesh machinery and the
    PR-2 backend layer meet on one compiled hot path.

Shard_map needs exact divisibility: each bucket's ``Kb`` must divide by the
number of subject shards — pass ``bucketize(subject_align=n_shards)`` (the
launchers do this automatically for ``--engine mesh``).

See docs/ARCHITECTURE.md (stage 6) for the full story.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:
    # newer jax: top-level; the experimental home was removed
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core import parafac2 as p2
from repro.dist import sharding as dsh

__all__ = ["ENGINES", "als_chunk_fn", "fit_device", "make_als_chunk",
           "make_als_while", "make_subject_update", "mesh_wrap"]

ENGINES = ("host", "scan", "mesh")


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------

def _default_mesh() -> Mesh:
    """All local devices as a 1-D data mesh (when no mesh is installed)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def _n_shards(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.devices.shape[mesh.axis_names.index(a)]
    return n


def _check_divisible(data, state, n_shards: int) -> None:
    for i, b in enumerate(data.buckets):
        if b.kb % n_shards:
            raise ValueError(
                f"engine='mesh' needs every bucket's subject count to divide "
                f"the {n_shards} subject shards, but bucket {i} has Kb={b.kb}; "
                f"re-bucketize with bucketize(subject_align={n_shards})")
    if isinstance(state.W, tuple):
        for i, wb in enumerate(state.W):
            if wb.shape[0] % n_shards:
                raise ValueError(
                    f"bucketed W shard {i} has Kb={wb.shape[0]}, not divisible "
                    f"by {n_shards} subject shards")


def _mesh_specs(data, state, axes: Tuple[str, ...]):
    """(data_specs, state_specs) pytrees for shard_map over the subject axis.

    Every Bucket leaf is Kb-leading → split over `axes`; H/V/fit (and a
    global [K,R] W) are replicated; a bucketed W tuple splits like the data.
    Constraint aux state (ADMM duals) follows its owning factor: the "w" aux
    of a bucketed W splits over the subject axes, everything else replicates.
    """
    lead = P(axes if len(axes) > 1 else axes[0])
    d_specs = jax.tree_util.tree_map(lambda _: lead, data)
    W = state.W
    w_spec = tuple(lead for _ in W) if isinstance(W, tuple) else P()
    aux = state.aux
    if isinstance(aux, dict):
        aux_specs = {
            k: jax.tree_util.tree_map(
                lambda _: lead if (k == "w" and isinstance(W, tuple)) else P(),
                sub)
            for k, sub in aux.items()}
    else:
        aux_specs = jax.tree_util.tree_map(lambda _: P(), aux)
    s_specs = p2.Parafac2State(H=P(), V=P(), W=w_spec, fit=P(), aux=aux_specs)
    return d_specs, s_specs


def _resolve_mesh() -> Tuple[Mesh, Tuple[str, ...]]:
    mesh = dsh.current_mesh()
    if mesh is None:
        mesh = _default_mesh()
    axes = dsh.subject_mesh_axes(mesh)
    if not axes:
        raise ValueError(
            f"engine='mesh': no 'subjects' rule axis present on mesh "
            f"{mesh.axis_names}; install axis_rules with a subjects entry")
    return mesh, axes


def _donate(donate: Optional[bool], argnum: int) -> Tuple[int, ...]:
    """State-carry donation argnums; defaults off on CPU (not implemented
    there — donating would just emit a warning per dispatch)."""
    if donate is None:
        donate = jax.default_backend() != "cpu"
    return (argnum,) if donate else ()


def mesh_wrap(fn: Callable, data, state, mesh: Optional[Mesh] = None,
              axes: Optional[Tuple[str, ...]] = None) -> Callable:
    """Wrap a ``(data, state) -> outputs`` ALS body in shard_map over the
    subjects bucket axis. `data`/`state` may be arrays or ShapeDtypeStructs
    (the dry-run lowers against specs). Every Bucket leaf (and bucketed-W
    shard) splits over the subject mesh axes; all other outputs — factor
    matrices, fit history, iteration counters — are replicated. Inside the
    body, cross-subject reductions route through
    :func:`repro.dist.sharding.psum_subjects` as explicit psums."""
    if mesh is None or axes is None:
        r_mesh, r_axes = _resolve_mesh()
        mesh = mesh if mesh is not None else r_mesh
        axes = axes if axes is not None else dsh.subject_mesh_axes(mesh)
    _check_divisible(data, state, _n_shards(mesh, axes))
    d_specs, s_specs = _mesh_specs(data, state, axes)

    def mapped_body(dd, ss):
        # entered during tracing of the shard_map body: psum_subjects
        # becomes lax.psum over `axes`, shard() constraints no-op
        with dsh.subject_collectives(axes):
            return fn(dd, ss)

    # out specs: probe the output structure (state leaves follow the input
    # state spec; everything else — fit history, counters — is replicated
    # R×R/scalar algebra).
    out_shapes = jax.eval_shape(fn, data, state)
    n_state = len(jax.tree_util.tree_leaves(s_specs))
    flat, treedef = jax.tree_util.tree_flatten(out_shapes)
    state_flat = jax.tree_util.tree_leaves(s_specs)
    out_flat = state_flat + [P()] * (len(flat) - n_state)
    out_specs = jax.tree_util.tree_unflatten(treedef, out_flat)
    return shard_map(mapped_body, mesh=mesh, in_specs=(d_specs, s_specs),
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# compiled chunk builders
# ---------------------------------------------------------------------------

def als_chunk_fn(opts: "p2.Parafac2Options", length: int) -> Callable:
    """The raw ``(data, state) -> (state, fits[length])`` chunk body:
    ``lax.scan`` over `length` ALS iterations, fit history as the scan ys.
    The dry-run lowers this directly; :func:`make_als_chunk` compiles it."""

    def chunk(d, s):
        def body(c, _):
            c2 = p2.als_step(d, c, opts)
            return c2, c2.fit
        return lax.scan(body, s, None, length=length)

    return chunk


def make_als_chunk(data, opts: "p2.Parafac2Options", length: int,
                   *, donate: Optional[bool] = None) -> Callable:
    """Compiled ``state -> (state, fits[length])``: `length` ALS iterations
    in one dispatch (``lax.scan``), fit history as the scan ys. For
    ``opts.engine == "mesh"`` the scan body runs inside shard_map with the
    data split over the subject axes."""
    return _compile(als_chunk_fn(opts, length), data, opts, donate=donate)


def make_als_while(data, opts: "p2.Parafac2Options", max_iters: int,
                   tol: float, *, donate: Optional[bool] = None) -> Callable:
    """Compiled ``state -> (state, hist[max_iters], n_iters)``: the whole
    fitting loop as ONE dispatch with on-device tol-based convergence —
    ``lax.while_loop`` with the host loop's exact stopping rule (stop after
    the first iteration ``i > 0`` with ``|fit_i - fit_{i-1}| < tol``)."""

    def run(d, s):
        hist0 = jnp.full((max_iters,), -jnp.inf, opts.dtype)

        def cond(carry):
            _, _, i, _, stop = carry
            return (i < max_iters) & ~stop

        def body(carry):
            s, hist, i, prev, _ = carry
            s2 = p2.als_step(d, s, opts)
            f = s2.fit.astype(hist.dtype)
            hist = lax.dynamic_update_index_in_dim(hist, f, i, 0)
            stop = (i > 0) & (jnp.abs(f - prev) < tol)
            return (s2, hist, i + 1, f, stop)

        init = (s, hist0, jnp.asarray(0, jnp.int32),
                jnp.asarray(-jnp.inf, opts.dtype), jnp.asarray(False))
        s, hist, n, _, _ = lax.while_loop(cond, body, init)
        return s, hist, n

    return _compile(run, data, opts, donate=donate)


def _compile(fn, data, opts, *, donate: Optional[bool]) -> Callable:
    """jit (and, for the mesh engine, shard_map) a (data, state) -> ... body;
    returns a state-only callable with `data` bound.

    The scan engine CLOSES OVER the data, exactly like the host loop's
    ``jax.jit(lambda s: als_step(data, s, opts))`` — constants vs runtime
    parameters change XLA's fusion decisions, and closing over keeps the
    scanned step bitwise identical to the host step. The mesh engine must
    pass the data as an argument instead (shard_map splits it via in_specs;
    a closed-over constant would be replicated per shard, double-counting
    every psum)."""
    if opts.engine == "mesh":
        mapped = None

        def call(d, s):
            nonlocal mapped
            if mapped is None:
                mapped = jax.jit(mesh_wrap(fn, d, s),
                                 donate_argnums=_donate(donate, argnum=1))
            return mapped(d, s)

        return lambda s: call(data, s)

    jitted = jax.jit(lambda s: fn(data, s),
                     donate_argnums=_donate(donate, argnum=0))
    return lambda s: jitted(s)


def make_subject_update(opts: "p2.Parafac2Options", *, smooth_lam: float = 0.0,
                        inner_iters: int = 1) -> Callable:
    """Compiled ``(batch, H, V, w_init, w_prev, prev_mask) -> (W, resid)``
    incremental-subject dispatch (:func:`repro.core.parafac2.update_subjects`).

    Unlike the fitting chunks, the DATA is a runtime argument here: the
    streaming service re-dispatches the same compiled program on every
    request batch, so the batch must not be baked in as a constant. jit's
    cache keys on the batch pytree structure + shapes — a service that pins
    its batch geometry (``repro.sparse.bucketing.fixed_plan`` + constant
    ``Bucketed`` aux metadata) compiles exactly once per (geometry, format)
    and every later flush is a cache hit.
    """

    def f(batch, H, V, w_init, w_prev, prev_mask):
        return p2.update_subjects(
            batch, H, V, opts, w_init=w_init, w_prev=w_prev,
            prev_mask=prev_mask, smooth_lam=smooth_lam,
            inner_iters=inner_iters)

    return jax.jit(f)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def fit_device(
    data,
    opts: "p2.Parafac2Options",
    *,
    max_iters: int = 100,
    tol: float = 1e-6,
    seed: int = 0,
    verbose: bool = False,
    state: Optional["p2.Parafac2State"] = None,
) -> Tuple["p2.Parafac2State", List[float]]:
    """Device-resident fitting loop (the ``engine="scan"|"mesh"`` halves of
    :func:`repro.core.parafac2.fit`; same signature and return contract)."""
    if opts.engine not in ENGINES:
        raise ValueError(f"unknown engine {opts.engine!r}; choose from {ENGINES}")
    if opts.engine == "host":
        raise ValueError("fit_device handles the device engines; "
                         "engine='host' is parafac2.fit's own loop")
    if opts.compress not in ("", "none"):
        # direct callers: the compression pass is host-side preprocessing
        # and lives ABOVE the engines — parafac2.fit compresses, then calls
        # back here with compress="none" on the core dataset.
        raise ValueError(
            f"fit_device runs the core ALS only (compress={opts.compress!r}); "
            f"route compressed fits through repro.core.parafac2.fit")
    if state is None:
        state = p2.init_state(data, opts, seed)

    if opts.check_every <= 0:
        # while_loop variant: one dispatch, on-device convergence
        run = make_als_while(data, opts, max_iters, tol)
        state, hist, n = run(state)
        n = int(n)
        history = [float(f) for f in np.asarray(hist[:n])]
        if verbose:
            print(f"[engine:{opts.engine}/while] {n} iters in one dispatch, "
                  f"fit={history[-1] if history else float('nan'):.6f}")
        return state, history

    # chunked-scan variant: ceil(max_iters / check_every) dispatches, one
    # host sync per chunk. Compiled chunks are cached by length (at most two
    # lengths: check_every and the final remainder).
    chunks: dict = {}
    history: List[float] = []
    prev = -np.inf
    done = False
    while len(history) < max_iters and not done:
        n = min(opts.check_every, max_iters - len(history))
        if n not in chunks:
            chunks[n] = make_als_chunk(data, opts, n)
        state, fits = chunks[n](state)
        fits = np.asarray(fits)            # ONE device sync per chunk
        for f in fits:
            history.append(float(f))
            if len(history) > 1 and abs(f - prev) < tol:
                done = True                # stop dispatching; keep the full
            prev = f                       # chunk so history[-1] == state.fit
        if verbose:
            print(f"[engine:{opts.engine}] iter {len(history) - 1:3d}  "
                  f"fit={history[-1]:.6f}")
    return state, history
