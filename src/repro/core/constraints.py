"""Pluggable constraint layer for PARAFAC2 factor updates (COPA-style AO-ADMM).

SPARTan's MTTKRP core is constraint-agnostic: every factor update consumes
only the MTTKRP ``M`` and the Gram matrix ``A`` of the fixed factors, so the
*same* hot loop supports a whole family of constrained models (COPA, Afshar
et al. 2018; tPARAFAC2, Chatzis et al. 2024) by swapping the small
``min_X ||T - X G^T||^2 + r(X)`` solve at the end. This module is that swap
point:

* a **registry** of named constraint terms (``register_term`` /
  ``available``), each a proximal operator plus solver metadata;
* a **spec grammar** — ``"name[:lam][+name[:lam]...]"`` per mode, e.g.
  ``"nonneg"``, ``"l1:0.1"``, ``"smooth:0.5"``, ``"nonneg+l1:0.1"`` — parsed
  by :func:`parse_spec` into a :class:`Constraint`;
* three **solver routes** per constraint:

  - ``ridge``  — the unconstrained ALS update (``nnls.ridge_solve``);
  - ``hals``   — HALS column sweeps (``nnls.hals_nnls``), the paper's
    nonnegativity path, preserved bitwise as the default;
  - ``admm``   — AO-ADMM (Huang et al. 2016): splitting
    ``X``/``Z = prox_{r/rho}``/dual ``U``, with the ``(Z, U)`` pair carried
    ACROSS outer ALS iterations as an opaque ``aux`` pytree inside
    ``Parafac2State`` (warm-started duals are what makes a handful of inner
    iterations per outer step sufficient).

Built-in terms: ``none``, ``nonneg`` (HALS), ``nonneg_admm`` (same feasible
set via ADMM clip-prox), ``l1`` (soft-threshold — sparse phenotypes),
``smooth`` (quadratic temporal smoothness on factor *rows*, tPARAFAC2-style:
``lam * sum_k ||x_k - x_{k-1}||^2``, prox = one tridiagonal solve).
``nonneg+l1`` composes in closed form (shrink-then-clip); compositions
without a closed-form joint prox raise at parse time.

``repro.core.parafac2.als_step`` routes every factor update (H, V, W — and
the per-bucket W layout) through :meth:`Constraint.update`; the engines
(scan / while / mesh in ``repro.core.engine``) carry the ADMM aux state like
any other ``Parafac2State`` leaf. See docs/ARCHITECTURE.md (stage 8).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.nnls import hals_nnls, ridge_solve

__all__ = [
    "MODES",
    "Constraint",
    "available",
    "bundle",
    "constraint_summary",
    "parse_constraint_arg",
    "parse_spec",
    "register_term",
]

MODES = ("h", "v", "w")   # PARAFAC2 factor modes a spec dict may constrain


# ---------------------------------------------------------------------------
# registry of atomic terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TermDef:
    """One registered constraint term.

    kind:        prox family — "none" | "clip" | "l1" | "smooth" | "custom"
    solver:      solver used when the term stands alone
    default_lam: strength when the spec omits ":lam"
    prox:        for kind="custom": ``prox(Y, rho, lam) -> Z`` (standalone
                 only; custom terms do not compose)
    nonneg:      solutions are guaranteed elementwise nonnegative
    """

    kind: str
    solver: str                      # "ridge" | "hals" | "admm"
    default_lam: float = 0.0
    prox: Optional[Callable] = None
    nonneg: bool = False


_REGISTRY: Dict[str, TermDef] = {}


def register_term(name: str, term: TermDef) -> None:
    """Register (or override) a named constraint term."""
    if term.kind == "custom" and term.prox is None:
        raise ValueError(f"custom term {name!r} needs a prox callable")
    _REGISTRY[name] = term
    if "parse_spec" in globals():          # built-ins register before it exists
        parse_spec.cache_clear()           # overrides must reach parsed specs


def available() -> Tuple[str, ...]:
    """Registered term names (sorted) — used in error messages and --help."""
    return tuple(sorted(_REGISTRY))


register_term("none", TermDef(kind="none", solver="ridge"))
register_term("nonneg", TermDef(kind="clip", solver="hals", nonneg=True))
register_term("nonneg_admm", TermDef(kind="clip", solver="admm", nonneg=True))
register_term("l1", TermDef(kind="l1", solver="admm", default_lam=0.1))
register_term("smooth", TermDef(kind="smooth", solver="admm", default_lam=0.1))


# ---------------------------------------------------------------------------
# prox operators
# ---------------------------------------------------------------------------

def prox_nonneg(Y: jax.Array) -> jax.Array:
    """Projection onto the nonnegative orthant."""
    return jnp.maximum(Y, 0.0)


def prox_l1(Y: jax.Array, t) -> jax.Array:
    """Soft-threshold: prox of ``t * ||.||_1`` (elementwise shrink)."""
    return jnp.sign(Y) * jnp.maximum(jnp.abs(Y) - t, 0.0)


def prox_nonneg_l1(Y: jax.Array, t) -> jax.Array:
    """Joint prox of nonnegativity + l1: shrink-then-clip (closed form)."""
    return jnp.maximum(Y - t, 0.0)


def prox_smooth(Y: jax.Array, rho, lam) -> jax.Array:
    """Prox of ``lam * sum_k ||y_k - y_{k-1}||^2`` over the leading axis.

    Minimizes ``rho/2 ||Z - Y||^2 + lam ||D Z||^2`` (D = first differences
    over rows): ``(rho I + 2 lam D^T D) Z = rho Y``, a symmetric tridiagonal
    system solved in O(K R) per call (``lax.linalg.tridiagonal_solve``).
    """
    K = Y.shape[0]
    if K < 2:
        return Y
    dt = Y.dtype
    rho = jnp.asarray(rho, dt)
    two_lam = jnp.asarray(2.0 * lam, dt)
    # D^T D diag = [1, 2, ..., 2, 1], off-diag = -1
    dtd_diag = jnp.full((K,), 2.0, dt).at[0].set(1.0).at[K - 1].set(1.0)
    d = rho + two_lam * dtd_diag
    off = jnp.full((K - 1,), -1.0, dt) * two_lam
    dl = jnp.concatenate([jnp.zeros((1,), dt), off])
    du = jnp.concatenate([off, jnp.zeros((1,), dt)])
    return lax.linalg.tridiagonal_solve(dl, d, du, rho * Y)


# ---------------------------------------------------------------------------
# spec parsing -> Constraint
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Constraint:
    """A parsed per-mode constraint: solver route + composed prox + aux shape.

    ``spec`` is the canonical string (stable across equivalent inputs);
    ``terms`` the resolved ``(name, lam)`` pairs. ``admm`` constraints carry
    ``(Z, U)`` dual state as an opaque pytree through ``Parafac2State.aux``.
    """

    spec: str
    terms: Tuple[Tuple[str, float], ...]

    # -- derived metadata ----------------------------------------------------
    @property
    def _defs(self) -> Tuple[TermDef, ...]:
        return tuple(_REGISTRY[n] for n, _ in self.terms)

    @property
    def solver(self) -> str:
        if len(self.terms) == 1:
            return self._defs[0].solver
        return "admm"

    @property
    def admm(self) -> bool:
        return self.solver == "admm"

    @property
    def nonneg(self) -> bool:
        """True when fitted factors are guaranteed elementwise nonnegative."""
        return any(d.nonneg for d in self._defs)

    @property
    def smooth_lam(self) -> float:
        return sum(lam for (n, lam), d in zip(self.terms, self._defs)
                   if d.kind == "smooth")

    @property
    def penalized(self) -> bool:
        """True when the constraint adds a PENALTY term (l1 / smooth /
        custom with lam > 0) rather than only an indicator (none / nonneg).
        The ALS loop skips column normalization for penalized modes: the
        penalized objective is not scale-invariant, and
        normalize-then-absorb-into-W would silently rescale the penalty
        every iteration."""
        return any(lam > 0 and d.kind not in ("none", "clip")
                   for (_, lam), d in zip(self.terms, self._defs))

    # -- composed prox -------------------------------------------------------
    def prox(self, Y: jax.Array, rho) -> jax.Array:
        """Joint prox of all terms at penalty ``rho`` (validated composable
        at parse time)."""
        kinds = {d.kind for d in self._defs}
        if "custom" in kinds:
            ((name, lam),), (d,) = self.terms, self._defs
            return d.prox(Y, rho, lam)
        if "smooth" in kinds:
            return prox_smooth(Y, rho, self.smooth_lam)
        l1_lam = sum(lam for (n, lam), d in zip(self.terms, self._defs)
                     if d.kind == "l1")
        t = l1_lam / rho
        if "clip" in kinds:
            return prox_nonneg_l1(Y, t) if l1_lam else prox_nonneg(Y)
        if l1_lam:
            return prox_l1(Y, t)
        return Y

    # -- aux (ADMM dual) state ----------------------------------------------
    def init_aux(self, x0: jax.Array):
        """Initial carried solver state for a factor shaped like ``x0``:
        ``(Z, U)`` for ADMM constraints, ``()`` otherwise."""
        if not self.admm:
            return ()
        return (self.prox(x0, jnp.asarray(1.0, x0.dtype)), jnp.zeros_like(x0))

    # -- the factor update ---------------------------------------------------
    def update(self, M: jax.Array, A: jax.Array, prev: jax.Array, aux,
               *, nnls_sweeps: int = 5, admm_iters: int = 10):
        """Solve ``min_X ||T - X G^T||^2 + r(X)`` given MTTKRP ``M = T G``
        and Gram ``A = G^T G``; returns ``(X, aux')``.

        ridge/hals routes are byte-for-byte the pre-refactor updates (the
        legacy ``nonneg`` flag's two branches); the admm route warm-starts
        from the carried ``(Z, U)`` pair and returns the updated pair.
        """
        if self.solver == "ridge":
            return ridge_solve(M, A), ()
        if self.solver == "hals":
            return hals_nnls(M, A, prev, sweeps=nnls_sweeps), ()
        if not aux:
            aux = self.init_aux(prev)
        return admm_solve(M, A, aux, self.prox, iters=admm_iters)


def _canon(name: str, lam: float, d: TermDef) -> str:
    return f"{name}:{lam:g}" if d.default_lam or lam else name


@functools.lru_cache(maxsize=None)
def parse_spec(spec: str) -> Constraint:
    """Parse ``"name[:lam][+...]"`` into a :class:`Constraint`.

    Unknown names raise ``ValueError`` listing the registered terms;
    compositions without a closed-form joint prox raise too.
    """
    raw = [p.strip() for p in str(spec).split("+") if p.strip()]
    if not raw:
        raw = ["none"]
    terms = []
    for part in raw:
        name, _, lam_s = part.partition(":")
        name = name.strip()
        if name not in _REGISTRY:
            raise ValueError(
                f"unknown constraint {name!r} in spec {spec!r}; "
                f"registered constraints: {', '.join(available())}")
        d = _REGISTRY[name]
        if lam_s and d.kind in ("none", "clip"):
            raise ValueError(
                f"constraint {name!r} is an indicator (no strength knob); "
                f"{part!r} is invalid")
        try:
            lam = float(lam_s) if lam_s else d.default_lam
        except ValueError:
            raise ValueError(f"bad strength {lam_s!r} in constraint {part!r}")
        if lam < 0:
            raise ValueError(f"negative strength in constraint {part!r}")
        terms.append((name, lam))
    # drop redundant "none" terms when composed with anything else
    if len(terms) > 1:
        terms = [t for t in terms if _REGISTRY[t[0]].kind != "none"] or terms[:1]
    kinds = [_REGISTRY[n].kind for n, _ in terms]
    if len(terms) > 1:
        if "custom" in kinds:
            raise ValueError(f"custom constraint terms do not compose: {spec!r}")
        if "smooth" in kinds:
            raise ValueError(
                f"no closed-form joint prox for {spec!r}: 'smooth' cannot be "
                f"composed with other terms (fit it on its own mode)")
        if not set(kinds) <= {"clip", "l1"}:
            raise ValueError(f"unsupported constraint composition {spec!r}")
    canon = "+".join(_canon(n, lam, _REGISTRY[n]) for n, lam in terms)
    return Constraint(spec=canon, terms=tuple(terms))


def bundle(specs: Mapping[str, str]) -> Dict[str, Constraint]:
    """Per-mode spec dict -> per-mode :class:`Constraint` dict (all of
    :data:`MODES` present; missing modes unconstrained)."""
    bad = set(specs) - set(MODES)
    if bad:
        raise ValueError(f"unknown constraint mode(s) {sorted(bad)}; "
                         f"valid modes: {MODES}")
    return {m: parse_spec(specs.get(m, "none")) for m in MODES}


def parse_constraint_arg(arg: str) -> Dict[str, str]:
    """Parse the driver syntax ``"v=nonneg+l1:0.1,w=smooth:0.1"``.

    A bare spec with no ``mode=`` prefix applies to both V and W (the two
    modes the paper constrains). Every spec is parsed eagerly so malformed
    input fails here with the registered-constraint listing.
    """
    out: Dict[str, str] = {}
    for part in (p.strip() for p in str(arg).split(",")):
        if not part:
            continue
        if "=" in part:
            mode, _, spec = part.partition("=")
            mode = mode.strip().lower()
            if mode not in MODES:
                raise ValueError(f"unknown constraint mode {mode!r} in "
                                 f"{arg!r}; valid modes: {MODES}")
            out[mode] = spec.strip()
        else:
            out.setdefault("v", part)
            out.setdefault("w", part)
    for mode, spec in out.items():
        parse_spec(spec)   # raises with the registered-constraint listing
    return out


def constraint_summary(specs: Mapping[str, str]) -> Dict[str, str]:
    """Canonicalized per-mode specs (the --json summary block)."""
    return {m: parse_spec(specs.get(m, "none")).spec for m in MODES}


# ---------------------------------------------------------------------------
# AO-ADMM inner solver
# ---------------------------------------------------------------------------

def admm_solve(M: jax.Array, A: jax.Array, aux, prox: Callable,
               *, iters: int = 10):
    """AO-ADMM for ``min_X ||T - X G^T||^2 + r(X)`` in normal form.

    M:    [N, R] MTTKRP result (T G)
    A:    [R, R] Gram (G^T G)
    aux:  warm-start ``(Z, U)`` from the previous outer ALS iteration
    prox: ``prox(Y, rho) -> Z``, the prox of r at penalty rho

    Splitting (Huang, Sidiropoulos & Liavas 2016; COPA §3):
        X  = (M + rho (Z - U)) (A + rho I)^{-1}     -- cholesky solve
        Z  = prox(X + U, rho)
        U += X - Z
    with the standard scaling ``rho = trace(A)/R``. Returns the *feasible*
    iterate Z and the updated ``(Z, U)`` carry.
    """
    R = A.shape[-1]
    dt = M.dtype
    rho = jnp.maximum(jnp.trace(A) / R, jnp.asarray(1e-12, A.dtype)).astype(dt)
    L = jnp.linalg.cholesky(A.astype(dt) + rho * jnp.eye(R, dtype=dt))

    def body(_, zu):
        Z, U = zu
        rhs = M + rho * (Z - U)
        X = jax.scipy.linalg.cho_solve((L, True), rhs.T).T
        Z = prox(X + U, rho)
        U = U + X - Z
        return (Z, U)

    Z, U = lax.fori_loop(0, iters, body, aux)
    return Z, (Z, U)


# ---------------------------------------------------------------------------
# aux-pytree helpers (used by the ALS step to keep scale absorption coherent)
# ---------------------------------------------------------------------------

def scale_aux(aux, col_scale: jax.Array):
    """Rescale every aux leaf columnwise — applied whenever the owning factor
    absorbs a column rescale, so warm-started duals stay aligned. A no-op
    (no leaves) for non-ADMM constraints."""
    return jax.tree_util.tree_map(lambda a: a * col_scale[None, :], aux)


def empty_aux() -> Dict[str, Any]:
    """The aux pytree of a fully direct (non-ADMM) constraint bundle."""
    return {m: () for m in MODES}
