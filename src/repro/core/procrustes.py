"""Batched orthogonal-Procrustes solvers for the PARAFAC2 Q_k step.

The paper (Kiers et al.) computes, per subject, the rank-R truncated SVD of
F_k = H S_k V^T X_k^T and sets Q_k = Z_k P_k^T. Observe F_k = B_k^T with
B_k = X_k V S_k H^T (I_k x R), and Q_k is then exactly the **orthogonal polar
factor** of B_k. Three batched solvers, trading generality for MXU-friendliness:

* ``polar_svd``          — jnp.linalg.svd of B_k (reference; O(I R^2) but LAPACK-style)
* ``polar_gram_eigh``    — eigh of the R x R Gram B^T B (default; O(I R^2) matmul
                           + O(R^3) eigh, batched, TPU-native)
* ``polar_newton_schulz``— pure-matmul Newton–Schulz iteration (no eigh at all)

All accept B of shape [Kb, I, R] and return Q of the same shape with
Q^T Q = I_R per subject (rows of padding are zero and stay zero in gram/NS).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["polar_svd", "polar_gram_eigh", "polar_newton_schulz", "solve_q"]


def polar_svd(B: jax.Array) -> jax.Array:
    """Reference batched polar factor via full SVD."""
    U, _, Vt = jnp.linalg.svd(B, full_matrices=False)
    return jnp.einsum("kir,krl->kil", U, Vt)


def polar_gram_eigh(B: jax.Array, *, eps: float = 1e-12) -> jax.Array:
    """Polar factor via eigendecomposition of the R x R Gram matrix.

    B = Q P with P = (B^T B)^{1/2};  Q = B P^{-1} = B E diag(1/sqrt(lam)) E^T.
    Rank-deficient directions get a zero inverse root (pseudo-polar), which is
    the correct limit for padded/empty subjects.
    """
    G = jnp.einsum("kir,kil->krl", B, B)                     # [Kb, R, R]
    lam, E = jnp.linalg.eigh(G)                               # ascending eigs
    scale = jnp.maximum(lam, 0.0)
    max_lam = jnp.max(scale, axis=-1, keepdims=True)
    tol = max_lam * eps
    inv_root = jnp.where(scale > tol, 1.0 / jnp.sqrt(jnp.maximum(scale, tol)), 0.0)
    P_inv = jnp.einsum("krl,kl,kml->krm", E, inv_root, E)     # E diag E^T
    return jnp.einsum("kir,krm->kim", B, P_inv)


def polar_newton_schulz(B: jax.Array, *, iters: int = 12) -> jax.Array:
    """Pure-matmul polar via Newton–Schulz: X <- 1.5 X - 0.5 X X^T X.

    Converges for ||B||_2 < sqrt(3); we pre-scale by the Frobenius norm.
    Matmul-only → maps to the MXU with no host fallback; good for large R.
    """
    norm = jnp.sqrt(jnp.einsum("kir,kir->k", B, B)) + 1e-30
    X = B / norm[:, None, None]

    def body(X, _):
        XtX = jnp.einsum("kir,kil->krl", X, X)
        X = 1.5 * X - 0.5 * jnp.einsum("kir,krl->kil", X, XtX)
        return X, None

    X, _ = jax.lax.scan(body, X, None, length=iters)
    return X


_SOLVERS = {
    "svd": polar_svd,
    "gram_eigh": polar_gram_eigh,
    "newton_schulz": polar_newton_schulz,
}


def solve_q(B: jax.Array, method: str = "gram_eigh", **kw) -> jax.Array:
    """Dispatch: batched Q_k = polar(B_k)."""
    try:
        fn = _SOLVERS[method]
    except KeyError:
        raise ValueError(f"unknown procrustes method {method!r}; options {sorted(_SOLVERS)}")
    return fn(B, **kw)
