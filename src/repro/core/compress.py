"""Preprocessing stage registry + the DPar2-style rsvd compression pass.

SPARTan made the per-iteration cost O(nnz); this module decouples iteration
count from data size the way DPar2 (PAPERS.md) does for irregular PARAFAC2:
*compress first*. Per bucket, a randomized QB decomposition collapses every
slice X_k [I_pad, J] to a small core G_k = P_k^T X_k [S, C_pad] behind an
orthonormal basis P_k [I_pad, S] (S = r + p sketch columns). The unchanged
ALS engines and the whole constraint layer then iterate on the cores — every
sweep costs O(S * C_pad * R) instead of O(I_pad * C_pad * R) — and the
fitted core factors expand *exactly* back to full space at the end:

  * **compression is format-aware, never densifying**: the sketch
    Y_k = X_k Ω and the power iterations route through the same bucket-level
    stages as ALS (:mod:`repro.kernels.sketch`) — dense tall-skinny matmuls
    on CC buckets, O(nnz) segment-sums on SCOO buckets;
  * **the cores ARE a dataset**: G_k shares X_k's kept-column metadata, so
    the core bucket is an ordinary CC :class:`~repro.core.irregular.Bucket`
    and the core :class:`~repro.core.irregular.Bucketed` flows through
    ``als_step``, every engine (host/scan/while/mesh) and every constraint
    without a single branch;
  * **the reported fit is the TRUE full-space fit**: for orthonormal P_k,
    ``||X_k - P_k M||^2 = ||G_k - M||^2 + (||X_k||^2 - ||G_k||^2)``, so the
    core dataset carries the ORIGINAL ``norm_sq`` and the engines' fit
    formula (norm_sq - 2*cross + model) evaluates the full-space residual of
    the expanded model at every iteration — no engine changes;
  * **expansion is a retraction, not an approximation**: polar(P B) =
    P polar(B) for orthonormal-column P, so the full-space Procrustes factor
    is Q_k = P_k Q̃_k with Q̃_k the core-space factor; H, V, W live in
    full space throughout. A final residual-correction pass
    (:func:`residual_correct`) re-evaluates the fit on the *original* data
    at the expanded Q_k (fresh, not one-step-stale) and replaces
    ``state.fit``.

The API mirrors the constraint layer (:mod:`repro.core.constraints`): a
**registry** of named preprocessors (:func:`register_preprocess` /
:func:`available`) and the same ``name[:param][+...]`` spec grammar parsed
fail-fast by :func:`parse_preprocess_spec` — unknown names raise
``ValueError`` listing the registered preprocessors. Built-ins:

  * ``none`` — identity (the default);
  * ``rsvd[:r[:p[:q]]]`` — randomized QB with target core rank ``r``
    (default ``2 * rank``), oversampling ``p`` (default 8) and ``q`` power
    iterations (default 1). Buckets whose padded row space is already
    <= r + p pass through uncompressed (mixed core datasets are fine — the
    auto backend routes per bucket).

``Parafac2Options(compress=...)`` threads a spec through :func:`fit`;
``--compress`` is the driver/benchmark twin. See docs/ARCHITECTURE.md
stage 10.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.irregular import Bucketed, bucket_format, cc_bucket_like
from repro.core.backend import get_backend
from repro.core.procrustes import polar_gram_eigh
from repro.dist.sharding import psum_subjects
from repro.kernels import sketch as _sketch
from repro.sparse.bucketing import route_compress

__all__ = [
    "CompressedBucket",
    "CompressedData",
    "Preprocess",
    "PreprocessDef",
    "available",
    "compress",
    "exact_fit",
    "expand_q",
    "fit_compressed",
    "parse_preprocess_spec",
    "preprocess_summary",
    "register_preprocess",
    "residual_correct",
]


# ---------------------------------------------------------------------------
# registry of named preprocessors (same shape as constraints._REGISTRY)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreprocessDef:
    """One registered preprocessing stage.

    param_names: ordered int parameters the spec may carry (``name:a:b:c``)
    defaults:    per-parameter default; 0 means "resolve at apply time"
    apply:       ``apply(pp, data, opts, seed) -> CompressedData``; None
                 marks the identity stage (fit() skips the whole pass)
    """

    param_names: Tuple[str, ...] = ()
    defaults: Tuple[int, ...] = ()
    apply: Optional[Callable] = None


_REGISTRY: Dict[str, PreprocessDef] = {}


def register_preprocess(name: str, d: PreprocessDef) -> None:
    """Register (or override) a named preprocessing stage."""
    if len(d.param_names) != len(d.defaults):
        raise ValueError(f"preprocess {name!r}: param_names/defaults mismatch")
    _REGISTRY[name] = d
    if "parse_preprocess_spec" in globals():   # built-ins register before it
        parse_preprocess_spec.cache_clear()    # overrides must reach parses


def available() -> Tuple[str, ...]:
    """Registered preprocessor names (sorted) — error messages and --help."""
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# spec parsing -> Preprocess
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Preprocess:
    """A parsed preprocessing spec: canonical string + resolved int params."""

    spec: str
    name: str
    params: Tuple[int, ...]

    @property
    def identity(self) -> bool:
        return _REGISTRY[self.name].apply is None

    def param(self, pname: str) -> int:
        d = _REGISTRY[self.name]
        return self.params[d.param_names.index(pname)]

    def sketch_dim(self, rank: int) -> int:
        """Basis width S = r + p; a bare ``rsvd`` resolves r to 2 * rank."""
        r = self.param("r") or 2 * rank
        if r < rank:
            raise ValueError(
                f"compress spec {self.spec!r}: core rank r={r} is below the "
                f"model rank {rank} — the cores cannot carry a rank-{rank} "
                f"model")
        return r + self.param("p")

    def apply(self, data: Bucketed, opts, *, seed: int = 0) -> "CompressedData":
        fn = _REGISTRY[self.name].apply
        if fn is None:
            raise ValueError(f"preprocess {self.spec!r} is the identity — "
                             f"nothing to apply")
        return fn(self, data, opts, seed)


@functools.lru_cache(maxsize=None)
def parse_preprocess_spec(spec: str) -> Preprocess:
    """Parse ``"name[:param][+...]"`` into a :class:`Preprocess`.

    The grammar is the constraint layer's: ``+``-composition is accepted
    syntactically (``none`` terms are dropped), but no two non-identity
    stages currently compose. Unknown names raise ``ValueError`` listing the
    registered preprocessors; non-integer or negative parameters fail fast.
    """
    raw = [p.strip() for p in str(spec).split("+") if p.strip()]
    if not raw:
        raw = ["none"]
    parts = []
    for part in raw:
        name, _, rest = part.partition(":")
        name = name.strip()
        if name not in _REGISTRY:
            raise ValueError(
                f"unknown preprocess {name!r} in spec {spec!r}; "
                f"registered preprocessors: {', '.join(available())}")
        d = _REGISTRY[name]
        given = [s.strip() for s in rest.split(":")] if rest else []
        if len(given) > len(d.param_names):
            raise ValueError(
                f"preprocess {name!r} takes at most {len(d.param_names)} "
                f"parameters ({':'.join(d.param_names)}); {part!r} has "
                f"{len(given)}")
        params = list(d.defaults)
        for i, tok in enumerate(given):
            try:
                params[i] = int(tok)
            except ValueError:
                raise ValueError(
                    f"bad {d.param_names[i]}={tok!r} in preprocess {part!r} "
                    f"(integer expected)")
            if params[i] < 0:
                raise ValueError(f"negative {d.param_names[i]} in "
                                 f"preprocess {part!r}")
        parts.append((name, tuple(params), len(given)))
    # drop redundant identity terms when composed with anything else
    if len(parts) > 1:
        parts = [t for t in parts if _REGISTRY[t[0]].apply is not None] \
            or parts[:1]
    if len(parts) > 1:
        raise ValueError(
            f"preprocessing stages do not compose: {spec!r} (pick one of "
            f"{', '.join(available())})")
    name, params, n_given = parts[0]
    canon = name + "".join(f":{v}" for v in params[:n_given])
    return Preprocess(spec=canon, name=name, params=params)


def preprocess_summary(spec: str, rank: Optional[int] = None) -> Dict[str, Any]:
    """Canonicalized compress block for the --json summaries."""
    pp = parse_preprocess_spec(spec)
    out: Dict[str, Any] = {"spec": pp.spec}
    if not pp.identity and rank is not None:
        out["sketch_dim"] = pp.sketch_dim(rank)
        out["power_iters"] = pp.param("q")
    return out


# ---------------------------------------------------------------------------
# the compressed representation
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CompressedBucket:
    """One bucket after the QB pass: orthonormal bases + the core bucket.

    basis: f[Kb, I_pad, S] per-subject orthonormal P_k (zero columns for
           rank-deficient directions and padding subjects), or None for a
           pass-through bucket (i_pad <= S already)
    core:  the small-core CC Bucket (vals = G_k = P_k^T X_k, [Kb, S, C_pad],
           sharing the original kept-column metadata) — or the ORIGINAL
           bucket, unchanged, when basis is None
    """

    basis: Optional[jax.Array]
    core: Any

    def tree_flatten(self):
        return (self.basis, self.core), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def compressed(self) -> bool:
        return self.basis is not None


@dataclasses.dataclass(frozen=True)
class CompressedData:
    """The full compressed dataset handed between compress -> fit -> expand.

    ``data`` is the core :class:`Bucketed` the engines iterate on. Its
    ``norm_sq`` is the ORIGINAL ``||X||_F^2`` — that constant offset is
    exactly what makes the engines' core-space residual the true full-space
    residual (see the module docstring identity). ``core_norm_sq`` keeps the
    cores' own energy ``sum_k ||G_k||^2`` for diagnostics (the captured-
    energy fraction is ``core_norm_sq / norm_sq``).
    """

    spec: str
    data: Bucketed
    buckets: List[CompressedBucket]
    sketch_dim: int
    core_norm_sq: float
    stats: List[dict]


# ---------------------------------------------------------------------------
# the rsvd pass
# ---------------------------------------------------------------------------

def compress(data: Bucketed, opts, pp: Preprocess, *,
             seed: int = 0) -> CompressedData:
    """Per-bucket randomized QB: X_k -> (P_k, G_k); cores become a Bucketed.

    One shared Gaussian Ω [J, S] sketches every bucket (so CC and SCOO
    layouts of the same data agree to numerical precision), the sketch and
    power iterations run through the bucket-level backend stages (SCOO
    buckets never densify), and ``polar_gram_eigh`` orthonormalizes — slices
    with fewer than S independent rows get exactly-zero basis columns, the
    correct degenerate limit. Buckets with ``i_pad <= S`` pass through
    uncompressed (compression would only add FLOPs).
    """
    S = pp.sketch_dim(opts.rank)
    q = pp.param("q")
    be = get_backend(opts.backend)
    # decorrelate the sketch from init_state's factor init at the same seed
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 0x5EED)
    Omega = _sketch.gaussian_sketch(key, data.n_cols, S, opts.dtype)
    route = route_compress([(b.i_pad, b.c_pad) for b in data.buckets], S)
    cbuckets: List[CompressedBucket] = []
    stats: List[dict] = []
    core_sq = 0.0
    for b, do_compress in zip(data.buckets, route):
        b_sq = float(jnp.sum(b.sq_norms()))
        rec = {"format": bucket_format(b), "i_pad": b.i_pad,
               "compressed": bool(do_compress)}
        if not do_compress:
            cbuckets.append(CompressedBucket(basis=None, core=b))
            core_sq += b_sq
            rec.update(core_rows=b.i_pad, energy=1.0)
        else:
            Y = be.sketch_bucket(b, Omega)                  # [Kb, I_pad, S]
            Y = _sketch.power_iterate(b, Y, q)
            P = polar_gram_eigh(Y) * b.subject_mask[:, None, None]
            G = b.project(P)                                # [Kb, S, C_pad]
            core = cc_bucket_like(b, G.astype(opts.dtype),
                                  row_counts=jnp.minimum(b.row_counts, S))
            cbuckets.append(CompressedBucket(basis=P, core=core))
            g_sq = float(jnp.sum(core.sq_norms()))
            core_sq += g_sq
            rec.update(core_rows=S, energy=g_sq / max(b_sq, 1e-30))
        stats.append(rec)
    core_data = Bucketed(
        buckets=[cb.core for cb in cbuckets],
        n_subjects=data.n_subjects,
        n_cols=data.n_cols,
        norm_sq=data.norm_sq,     # ORIGINAL norm: engine fit is full-space
    )
    return CompressedData(spec=pp.spec, data=core_data, buckets=cbuckets,
                          sketch_dim=S, core_norm_sq=core_sq, stats=stats)


register_preprocess("none", PreprocessDef())
register_preprocess("rsvd", PreprocessDef(
    param_names=("r", "p", "q"), defaults=(0, 8, 1),
    apply=lambda pp, data, opts, seed: compress(data, opts, pp, seed=seed)))


# ---------------------------------------------------------------------------
# expansion + the residual-correction pass
# ---------------------------------------------------------------------------

def expand_q(comp: CompressedData, state, opts) -> List[jax.Array]:
    """Full-space Procrustes factors per bucket: Q_k = P_k Q̃_k.

    Q̃_k is the core-space factor at the fitted state (recomputed through
    the ordinary Procrustes stage on the core bucket — the engines never
    store Q). For orthonormal-column P the product IS the polar factor of
    the full-space target, so this is a retraction, not an approximation.
    """
    from repro.core import parafac2 as p2

    be = get_backend(opts.backend)
    out: List[jax.Array] = []
    for i, cb in enumerate(comp.buckets):
        _, _, Qc = p2._procrustes_project(
            cb.core, state.H, state.V, state.W, opts, i, be)
        if cb.basis is None:
            out.append(Qc)
        else:
            out.append(jnp.einsum("kis,ksr->kir", cb.basis, Qc))
    return out


def exact_fit(data: Bucketed, state, opts, Qs: List[jax.Array]) -> jax.Array:
    """Full-space model fit on the ORIGINAL data at explicit Q_k factors.

    Same R x R algebra as the ``als_step`` fit stage, but with fresh (not
    one-step-stale) Q and the original buckets — this is the residual-
    correction pass that certifies the expanded factors.
    """
    from repro.core import parafac2 as p2

    be = get_backend(opts.backend)
    H, V, W = state.H, state.V, state.W
    VtV = V.T @ V
    Phi = H.T @ H
    delta = jnp.zeros((), opts.dtype)
    for i, (b, Q) in enumerate(zip(data.buckets, Qs)):
        proj = be.project_bucket(b, Q)
        G = be.ykv_bucket(b, proj, V)                       # [Kb, R, R]
        Wb = p2._w_rows(W, b, i)
        cross = jnp.einsum("rl,krl,kl,k->", H, G, Wb, b.subject_mask)
        model = jnp.einsum("rl,rl,kr,kl,k->", Phi, VtV, Wb, Wb,
                           b.subject_mask)
        delta = delta - 2.0 * cross + model
    norm_sq = jnp.asarray(data.norm_sq, opts.dtype)
    resid = norm_sq + psum_subjects(delta)
    return 1.0 - jnp.sqrt(jnp.maximum(resid, 0.0)) / jnp.sqrt(norm_sq)


def residual_correct(data: Bucketed, comp: CompressedData, state, opts):
    """Replace ``state.fit`` with the exact full-space fit at the expanded
    factors (H, V, W are full-space already; only Q needs expansion)."""
    Qs = expand_q(comp, state, opts)
    return state._replace(fit=exact_fit(data, state, opts, Qs))


def fit_compressed(data: Bucketed, opts, *, max_iters: int = 100,
                   tol: float = 1e-6, seed: int = 0, verbose: bool = False,
                   state=None):
    """compress -> core ALS (unchanged engines) -> expand + correct.

    The entry point ``repro.core.parafac2.fit`` routes here whenever
    ``opts.compress`` names a non-identity stage. Returns the usual
    ``(state, history)`` with full-space factors; the last history entry is
    replaced by the residual-corrected exact fit.
    """
    from repro.core import parafac2 as p2

    pp = parse_preprocess_spec(opts.compress)
    core_opts = dataclasses.replace(opts, compress="none")
    if pp.identity:
        return p2.fit(data, core_opts, max_iters=max_iters, tol=tol,
                      seed=seed, verbose=verbose, state=state)
    comp = pp.apply(data, core_opts, seed=seed)
    if verbose:
        frac = comp.core_norm_sq / max(comp.data.norm_sq, 1e-30)
        print(f"[compress] {pp.spec}: sketch_dim={comp.sketch_dim}, "
              f"{sum(s['compressed'] for s in comp.stats)}/"
              f"{len(comp.stats)} buckets compressed, "
              f"captured energy {frac:.4f}")
    state, history = p2.fit(comp.data, core_opts, max_iters=max_iters,
                            tol=tol, seed=seed, verbose=verbose, state=state)
    state = residual_correct(data, comp, state, core_opts)
    if history:
        history[-1] = float(state.fit)
    return state, history
