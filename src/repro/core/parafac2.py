"""PARAFAC2-ALS with the SPARTan MTTKRP — the paper's full fitting algorithm.

One ALS iteration (Algorithm 2 of the paper) on the bucketed CC format:

  1. Procrustes step (batched over subjects): B_k = X_k V S_k H^T,
     Q_k = polar(B_k)  (Gram-eigh by default — see procrustes.py).
  2. Project: Y_k = Q_k^T X_k  (CC: shares X_k's kept-column ids).
  3. ONE CP-ALS iteration on {Y_k} via the SPARTan mode-1/2/3 MTTKRPs; each
     factor update (H from M1, V from M2, W from M3 and its Gram) routes
     through the per-mode constraint layer (:mod:`repro.core.constraints`,
     ``opts.constraints`` — COPA-style AO-ADMM; the default reproduces the
     paper's H <- unconstrained solve, V/W <- HALS nnls bitwise, and
     ADMM-routed constraints carry their dual state in ``state.aux``);
     S_k = diag(W(k,:)).
  4. Fit = 1 - sqrt(sum_k ||X_k - Q_k H S_k V^T||^2) / ||X||_F.

Everything inside :func:`als_step` is jit/pjit-compatible; subjects shard over
the leading bucket axis (the "subjects" rule in :mod:`repro.dist.sharding`;
``launch/dryrun.py::run_parafac2_cell`` lowers this step on a production
mesh). ``mode1_reuse=True`` enables the beyond-paper optimization
Y_k V = Q_k^T (X_k V) (cached from step 1). The three MTTKRPs dispatch
through a pluggable compute backend (``opts.backend``: "jnp" | "pallas" |
"scoo" | "auto" — see :mod:`repro.core.backend`), so the same ALS algebra
runs the pure-jnp SPARTan math, the Pallas TPU kernels, or the O(nnz)
SCOO-native segment-sum route — per bucket, since a ``bucketize(
format="auto")`` dataset mixes CC and SCOO buckets. See docs/ARCHITECTURE.md
(stages 3-5 and the SCOO stage) for the full data flow and sharding story.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.irregular import Bucket, Bucketed
from repro.core.backend import MttkrpBackend, get_backend
from repro.core import compress as _compress
from repro.core import constraints as cst
from repro.core.cp import normalize_columns
from repro.core.procrustes import solve_q
from repro.dist.sharding import psum_subjects

__all__ = ["Parafac2State", "Parafac2Options", "constraints_for", "init_state",
           "als_step", "fit", "reconstruct_uk", "update_subjects", "w_global"]


class Parafac2State(NamedTuple):
    H: jax.Array          # [R, R]
    V: jax.Array          # [J, R]
    W: jax.Array          # [K, R]  (S_k = diag(W[k]))
    fit: jax.Array        # scalar, model fit in [−inf, 1]
    # opaque per-mode constraint-solver state (ADMM duals), carried across
    # iterations by every engine like any other leaf: {"h": .., "v": .., "w": ..}
    # with () for modes whose constraint is direct (none/nonneg).
    aux: Any = ()


@dataclasses.dataclass(frozen=True)
class Parafac2Options:
    rank: int
    # Per-mode constraint specs, {"v": "nonneg+l1:0.1", "w": "smooth:0.5", ...}
    # (modes "h"/"v"/"w"; missing modes unconstrained — see
    # repro.core.constraints for the spec grammar and registry). None selects
    # the legacy behaviour: nonneg on V and W as in the paper.
    constraints: Optional[Union[Mapping[str, str], Tuple]] = None
    # Preprocessing stage spec ("none" | "rsvd[:r[:p[:q]]]" | any registered
    # preprocessor — see repro.core.compress). Non-identity stages make fit()
    # compress the data first, run the UNCHANGED core ALS on the small cores,
    # and expand + residual-correct at the end.
    compress: str = "none"
    # REMOVED (was deprecated in the constraint-layer PR): the
    # pre-constraint-layer nonneg bool. Passing it raises TypeError with the
    # migration hint below; the InitVar keeps the error message better than
    # a bare "unexpected keyword argument".
    nonneg: dataclasses.InitVar[Optional[bool]] = None
    procrustes: str = "gram_eigh"       # "svd" | "gram_eigh" | "newton_schulz"
    mode1_reuse: bool = True            # beyond-paper: reuse X_k V from step 1
    nnls_sweeps: int = 5
    # inner AO-ADMM iterations per factor update (admm-routed constraints;
    # warm-started duals make a handful sufficient — COPA §3)
    admm_iters: int = 10
    # Tikhonov damping added to every factor update's R x R Gram
    # (A + ridge*I). 0.0 — the default — is a STATIC no-op: the term is
    # gated at trace time, so the emitted HLO (and therefore the fit
    # trajectory) is bitwise the historical one. The fault supervisor
    # (repro.dist.supervisor) raises it on its tightened-regularization
    # retry after repeated numerical-health rollbacks.
    ridge: float = 0.0
    dtype: Any = jnp.float32
    # MTTKRP compute backend: "jnp" (pure-jnp spartan math, exact reference),
    # "pallas" (TPU kernels; interpret-mode emulation off-TPU), "scoo" (the
    # O(nnz) SCOO-native route on SparseBucket data, jnp on CC buckets), or
    # "pallas" (TPU kernels; interpret-mode emulation off-TPU), "scoo" (the
    # O(nnz) SCOO-native route on SparseBucket data, jnp on CC buckets),
    # "fused" (the fused ALS megakernel stages — four double-buffered slab
    # passes per bucket per iteration, Y_k never materialized), or "auto"
    # (scoo for SCOO buckets; fused on TPU for kernel-friendly CC bucket
    # geometry, jnp otherwise). See repro.core.backend.
    backend: str = "auto"
    # Compute precision for the streamed operands: "f32" (default — bitwise
    # the historical behaviour), "bf16" or "f16" (slab values staged
    # half-width, every dot still accumulates f32 via accum_dtype; pairs
    # with dtype=f32 factors). See repro.kernels.common.
    precision: str = "f32"
    # W layout: "global" [K,R] (simple, interpretable) or "bucketed" (tuple of
    # per-bucket [Kb,R] rows aligned with the data shards — no W gathers under
    # pjit; the layout production runs use, §Perf 'bucketed W').
    w_layout: str = "global"
    # Execution engine for fit() (see repro.core.engine):
    #   "host"  — one jitted als_step dispatch per iteration, host-side
    #             convergence check (the exact reference loop);
    #   "scan"  — device-resident lax.scan over chunks of `check_every`
    #             iterations per dispatch, donated state carry, fit history
    #             accumulated on device (convergence checked per chunk);
    #   "mesh"  — the scanned step additionally wrapped in shard_map over the
    #             subjects bucket axis (explicit psums at the cross-subject
    #             reductions; dist.sharding.subject_mesh_axes picks the axes).
    engine: str = "host"
    # Iterations per device dispatch for the scan/mesh engines. 0 selects the
    # lax.while_loop variant: the whole run is ONE dispatch with the tol
    # check evaluated on device (exact host stopping semantics).
    check_every: int = 10

    def __post_init__(self, nonneg):
        if nonneg is not None:
            raise TypeError(
                "Parafac2Options(nonneg=...) was removed (it shipped one "
                "release as a DeprecationWarning shim); migrate to "
                "constraints={'v': 'nonneg', 'w': 'nonneg'} for nonneg=True "
                "or {'v': 'none', 'w': 'none'} for nonneg=False")
        if self.constraints is not None:
            # normalize to a hashable, canonically ordered tuple of pairs
            object.__setattr__(
                self, "constraints", tuple(sorted(dict(self.constraints).items())))
        # fail fast on a bad preprocessing spec (ValueError listing the
        # registered preprocessors), exactly like constraint specs do
        _compress.parse_preprocess_spec(self.compress)
        if self.ridge < 0.0:
            raise ValueError(f"ridge must be >= 0, got {self.ridge}")
        from repro.kernels.common import PRECISIONS
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; "
                f"choose from {PRECISIONS}")
        if self.precision != "f32" and jnp.dtype(self.dtype) == jnp.float64:
            raise ValueError(
                "precision='bf16'/'f16' casts the streamed operands below "
                "the requested f64 factor dtype; use dtype=float32 with "
                "reduced precision, or precision='f32' with f64")

    def constraint_specs(self) -> Dict[str, str]:
        """Resolved per-mode constraint specs (``constraints=None`` keeps the
        paper's nonnegative V/W default)."""
        if self.constraints is not None:
            return dict(self.constraints)
        return {"v": "nonneg", "w": "nonneg"}


def constraints_for(opts: Parafac2Options) -> Dict[str, cst.Constraint]:
    """Parsed per-mode :class:`repro.core.constraints.Constraint` bundle for
    ``opts`` (parse results are cached on the spec strings), with the
    layout/constraint compatibility checks applied."""
    cons = cst.bundle(opts.constraint_specs())
    if opts.w_layout == "bucketed" and cons["w"].smooth_lam:
        raise ValueError(
            "constraint 'smooth' on mode 'w' couples W rows across subjects "
            "and needs w_layout='global' (the bucketed layout splits rows "
            "across data shards)")
    return cons


def init_state(data: Bucketed, opts: Parafac2Options, seed: int = 0) -> Parafac2State:
    """H = I, V random (nonneg if constrained), W = 1 — Kiers-style init.

    ADMM-routed constraints get their ``(Z, U)`` dual state initialized here
    so the carried ``aux`` pytree has a static structure for the engines.
    """
    R = opts.rank
    cons = constraints_for(opts)
    key = jax.random.PRNGKey(seed)
    H = jnp.eye(R, dtype=opts.dtype)
    if cons["v"].nonneg:
        V = jax.random.uniform(key, (data.n_cols, R), opts.dtype)
    else:
        V = jax.random.normal(key, (data.n_cols, R), opts.dtype)
    if opts.w_layout == "bucketed":
        W = tuple(jnp.ones((b.kb, R), opts.dtype) * b.subject_mask[:, None]
                  for b in data.buckets)
    else:
        W = jnp.ones((data.n_subjects, R), opts.dtype)
    if isinstance(W, tuple):
        # per-bucket aux (a LIST, so pytree structure distinguishes it from
        # the global layout's single (Z, U) pair)
        aux_w = [cons["w"].init_aux(wb) for wb in W] if cons["w"].admm else ()
    else:
        aux_w = cons["w"].init_aux(W)
    aux = {"h": cons["h"].init_aux(H), "v": cons["v"].init_aux(V), "w": aux_w}
    return Parafac2State(H=H, V=V, W=W, fit=jnp.asarray(-jnp.inf, opts.dtype),
                         aux=aux)


def _w_rows(W, b: Bucket, i: int):
    """W rows for bucket i (no gather in the bucketed layout)."""
    if isinstance(W, tuple):
        return W[i]
    return jnp.take(W, b.subject_ids, axis=0)


def _w_gram(W):
    if isinstance(W, tuple):
        # bucketed W is sharded with the data: the gram is a cross-subject
        # reduction (global W is replicated, so no psum on that branch)
        return psum_subjects(sum(wb.T @ wb for wb in W))
    return W.T @ W


def w_global(data: Bucketed, W) -> jnp.ndarray:
    """Assemble a global [K, R] W from either layout (interpretation)."""
    if not isinstance(W, tuple):
        return W
    R = W[0].shape[1]
    out = jnp.zeros((data.n_subjects, R), W[0].dtype)
    for b, wb in zip(data.buckets, W):
        out = out.at[b.subject_ids].add(wb * b.subject_mask[:, None])
    return out


def _ridged(A: jax.Array, opts: Parafac2Options) -> jax.Array:
    """A + ridge*I on an R x R Gram; trace-time no-op at ridge == 0 (the
    default emits the identical HLO — bitwise-safe)."""
    if opts.ridge:
        return A + jnp.asarray(opts.ridge, A.dtype) * jnp.eye(
            A.shape[-1], dtype=A.dtype)
    return A


def _procrustes_project(
    b: Bucket, H: jax.Array, V: jax.Array, W: jax.Array, opts: Parafac2Options,
    i: int = 0, be: Optional[MttkrpBackend] = None,
) -> Tuple[Any, jax.Array, jax.Array]:
    """Steps 1+2 for one bucket -> (proj, XkV, Q).

    ``proj`` is the backend's per-bucket projected representation
    (:meth:`MttkrpBackend.project_bucket`): the compact Yc [Kb, R, C] on the
    dense route, Q itself on the SCOO-native and fused routes (where Y_k is
    never materialized). ``als_step`` only ever hands it back to the same
    backend.
    """
    be = get_backend(opts.backend, opts.precision) if be is None else be
    Vg = b.gather_v(V)                                   # [Kb, C, R]
    Wb = _w_rows(W, b, i)                                # [Kb, R]
    # B_k = X_k V S_k H^T  == (XkV * w_k) @ H^T — one fused slab pass on the
    # fused route, xkv + a small einsum on the staged ones
    XkV, B = be.procrustes_b_bucket(b, H, Wb, V, Vg)     # [Kb, I, R] x2
    Q = solve_q(B, opts.procrustes)                      # [Kb, I, R]
    Q = be.shard_subjects(Q * b.subject_mask[:, None, None])
    proj = be.project_bucket(b, Q)
    return proj, XkV, Q


def als_step(
    data: Bucketed,
    state: Parafac2State,
    opts: Parafac2Options,
) -> Parafac2State:
    """One full PARAFAC2-ALS iteration (jit-compatible).

    Every factor update routes through the per-mode constraint bundle
    (:func:`constraints_for`); ADMM-routed constraints read and write their
    dual state in ``state.aux`` — the engines carry it like any other leaf.
    """
    H, V, W = state.H, state.V, state.W
    R, J, K = opts.rank, data.n_cols, data.n_subjects
    be = get_backend(opts.backend, opts.precision)
    cons = constraints_for(opts)
    solve_kw = dict(nnls_sweeps=opts.nnls_sweeps, admm_iters=opts.admm_iters)
    aux = state.aux if isinstance(state.aux, dict) else cst.empty_aux()

    bucketed = isinstance(W, tuple)

    def scale_w(W, norms):
        if isinstance(W, tuple):
            return tuple(wb * norms[None, :] for wb in W)
        return W * norms[None, :]

    # ---- 1+2: Procrustes + projection, per bucket --------------------------
    per_bucket = [_procrustes_project(b, H, V, W, opts, i, be)
                  for i, b in enumerate(data.buckets)]

    # ---- 3a: H update (mode-1 MTTKRP) --------------------------------------
    M1 = jnp.zeros((R, R), opts.dtype)
    for i, (b, (proj, XkV, Q)) in enumerate(zip(data.buckets, per_bucket)):
        Wb = _w_rows(W, b, i)
        if opts.mode1_reuse:
            # Y_k V = Q_k^T (X_k V): skip the gather+matmul on sparse data
            # (fused backends reduce M1 in the same dispatch that forms YkV)
            M1 = M1 + be.mode1_xkv_bucket(b, Q, XkV, Wb)
        else:
            M1 = M1 + be.mode1_bucket(b, proj, Wb, V)
    M1 = psum_subjects(M1)
    H_new, aux_h = cons["h"].update(M1, _ridged(_w_gram(W) * (V.T @ V), opts),
                                    H, aux["h"], **solve_kw)
    aux_w = aux["w"]
    if not cons["h"].penalized:
        # absorb scale into W (model-invariant for indicator constraints;
        # penalized modes keep their natural scale — see Constraint.penalized)
        H_new, h_norms = normalize_columns(H_new)
        aux_h = cst.scale_aux(aux_h, 1.0 / jnp.maximum(h_norms, 1e-12))
        W = scale_w(W, h_norms)
        aux_w = cst.scale_aux(aux_w, h_norms)

    # ---- 3b: V update (mode-2 MTTKRP) --------------------------------------
    M2 = jnp.zeros((J, R), opts.dtype)
    for i, (b, (proj, _, _)) in enumerate(zip(data.buckets, per_bucket)):
        Wb = _w_rows(W, b, i)
        A = be.mode2_bucket(b, proj, H_new, Wb)
        M2 = M2 + be.mode2_scatter(A, b.cols, J).astype(M2.dtype)
    M2 = psum_subjects(M2)
    V_new, aux_v = cons["v"].update(
        M2, _ridged(_w_gram(W) * (H_new.T @ H_new), opts), V,
        aux["v"], **solve_kw)
    if not cons["v"].penalized:
        V_new, v_norms = normalize_columns(V_new)
        aux_v = cst.scale_aux(aux_v, 1.0 / jnp.maximum(v_norms, 1e-12))
        W = scale_w(W, v_norms)
        aux_w = cst.scale_aux(aux_w, v_norms)

    # ---- 3c: W update (mode-3 MTTKRP) --------------------------------------
    VtV = V_new.T @ V_new
    gram3 = _ridged(VtV * (H_new.T @ H_new), opts)
    rows_per_bucket = []
    Gs = []   # G_k = Y_k V_new per bucket, shared with the fit computation
    for b, (proj, _, _) in zip(data.buckets, per_bucket):
        G = be.ykv_bucket(b, proj, V_new)
        Gs.append(G)
        rows_per_bucket.append(
            be.mode3_bucket(b, proj, H_new, YkV=G))
    if bucketed:
        # per-bucket W rows update in place — no K-wide scatter, no gathers;
        # per-bucket aux rides in a list aligned with the buckets
        aux_w_list = (aux_w if isinstance(aux_w, list)
                      else [() for _ in data.buckets])
        upd = [cons["w"].update(rows.astype(wb.dtype), gram3, wb, awb,
                                **solve_kw)
               for rows, wb, awb in zip(rows_per_bucket, W, aux_w_list)]
        W_new = tuple(wn * b.subject_mask[:, None]
                      for (wn, _), b in zip(upd, data.buckets))
        aux_w = [a for _, a in upd] if cons["w"].admm else ()
    else:
        M3 = jnp.zeros((K, R), opts.dtype)
        for b, rows in zip(data.buckets, rows_per_bucket):
            M3 = M3.at[b.subject_ids].add(rows.astype(M3.dtype))
        M3 = psum_subjects(M3)
        W_new, aux_w = cons["w"].update(M3, gram3, W, aux_w, **solve_kw)

    # ---- 4: fit ------------------------------------------------------------
    # ||X_k - Q_k H S_k V^T||^2 = ||X||^2 - 2 tr(S H^T G_k) + tr(S Φ S V^T V),
    # with G_k = Y_k V_new and Φ = H^T H — all R x R algebra.
    Phi = H_new.T @ H_new
    delta = jnp.zeros((), opts.dtype)
    for i, b in enumerate(data.buckets):
        G = Gs[i]                                              # [Kb, R, R]
        Wb = _w_rows(W_new, b, i)                              # [Kb, R]
        cross = jnp.einsum("rl,krl,kl,k->", H_new, G, Wb, b.subject_mask)
        model = jnp.einsum("rl,rl,kr,kl,k->", Phi, VtV, Wb, Wb, b.subject_mask)
        delta = delta - 2.0 * cross + model
    resid = jnp.asarray(data.norm_sq, opts.dtype) + psum_subjects(delta)
    fit_val = 1.0 - jnp.sqrt(jnp.maximum(resid, 0.0)) / jnp.sqrt(
        jnp.asarray(data.norm_sq, opts.dtype))

    return Parafac2State(H=H_new, V=V_new, W=W_new, fit=fit_val,
                         aux={"h": aux_h, "v": aux_v, "w": aux_w})


def fit(
    data: Bucketed,
    opts: Parafac2Options,
    *,
    max_iters: int = 100,
    tol: float = 1e-6,
    seed: int = 0,
    verbose: bool = False,
    state: Optional[Parafac2State] = None,
) -> Tuple[Parafac2State, List[float]]:
    """Full fitting loop with fit-change convergence.

    ``opts.compress`` (a :mod:`repro.core.compress` spec) runs the whole loop
    on randomized small cores: compress -> this same function with
    ``compress="none"`` on the core dataset -> exact expand + residual
    correction. ``opts.engine`` picks the execution engine: "host" is the
    reference loop below (one jitted dispatch + one device sync per
    iteration); "scan" and "mesh" run device-resident compiled chunks (see
    :mod:`repro.core.engine`).
    """
    if not _compress.parse_preprocess_spec(opts.compress).identity:
        return _compress.fit_compressed(data, opts, max_iters=max_iters,
                                        tol=tol, seed=seed, verbose=verbose,
                                        state=state)
    if opts.engine != "host":
        from repro.core import engine as _engine
        return _engine.fit_device(data, opts, max_iters=max_iters, tol=tol,
                                  seed=seed, verbose=verbose, state=state)
    if state is None:
        state = init_state(data, opts, seed)
    step = jax.jit(lambda s: als_step(data, s, opts))
    history: List[float] = []
    prev = -np.inf
    for it in range(max_iters):
        state = step(state)
        f = float(state.fit)
        history.append(f)
        if verbose:
            print(f"iter {it:3d}  fit={f:.6f}")
        if it > 0 and abs(f - prev) < tol:
            break
        prev = f
    return state, history


def update_subjects(
    batch: Bucketed,
    H: jax.Array,
    V: jax.Array,
    opts: Parafac2Options,
    *,
    w_init: Optional[jax.Array] = None,
    w_prev: Optional[jax.Array] = None,
    prev_mask: Optional[jax.Array] = None,
    smooth_lam: float = 0.0,
    inner_iters: int = 1,
) -> Tuple[jax.Array, jax.Array]:
    """Incremental per-subject solve with the factors ``H``/``V`` FIXED.

    This is the streaming/serving entry point (ROADMAP item 1, the tPARAFAC2
    append workload): given a fitted model, a new or touched subject only
    needs its own Procrustes basis ``Q_k`` and its own W row — both
    independent across subjects, so a request batch is ONE padded, jitted
    dispatch. Per inner iteration (all batched over subjects, per bucket,
    through the same bucket-level backend stages ``als_step`` uses — the
    CC/SCOO format split is free):

      1. ``B_k = X_k V S_k H^T``, ``Q_k = polar(B_k)``   (Procrustes at the
         current w_k; ``w_init`` on the first pass),
      2. ``G_k = Y_k V`` and the mode-3 MTTKRP row, then the W-row solve
         through ``opts``' "w" constraint — exactly the ``als_step`` stage-3c
         update (with ``smooth_lam == 0`` and ``inner_iters == 1`` this IS
         that stage, on a batch holding only the touched subjects).

    ``smooth_lam > 0`` adds the tPARAFAC2-style temporal anchor
    ``lam * ||w_k - w_k^prev||^2`` for subjects with a previous row
    (``prev_mask``): a quadratic penalty folds EXACTLY into the normal
    equations (``M += lam w_prev``, ``A += lam I``), so every solver route
    (ridge/HALS/ADMM) stays exact — but ``A`` becomes per-subject, so that
    branch solves rows under ``vmap``. New subjects (mask 0) are unpenalized.

    ADMM-routed W constraints start from fresh duals here (requests are
    independent one-shot solves; there is no outer ALS loop to warm-start
    across) — raise ``opts.admm_iters`` if a tight ADMM solve matters.

    Returns ``(W_rows [batch.n_subjects, R], resid [batch.n_subjects])``
    where ``resid[k] = ||X_k - Q_k H S_k V^T||_F^2`` at the returned row
    (same algebra as the ``als_step`` fit, per subject) — the streaming
    service's drift tracker sums these into an exact union-dataset fit.
    Jit-compatible; compile once per batch geometry via
    :func:`repro.core.engine.make_subject_update`.
    """
    if inner_iters < 1:
        raise ValueError(f"inner_iters must be >= 1, got {inner_iters}")
    R = opts.rank
    be = get_backend(opts.backend, opts.precision)
    cons_w = constraints_for(opts)["w"]
    solve_kw = dict(nnls_sweeps=opts.nnls_sweeps, admm_iters=opts.admm_iters)
    VtV = V.T @ V
    Phi = H.T @ H
    gram3 = VtV * Phi                                     # [R, R]

    if w_init is None:
        w_init = jnp.ones((batch.n_subjects, R), opts.dtype)
    if w_prev is None:
        w_prev = jnp.zeros((batch.n_subjects, R), opts.dtype)
    if prev_mask is None:
        prev_mask = jnp.zeros((batch.n_subjects,), opts.dtype)

    def _row_solve(rows, wb, prevb, pmaskb):
        """The stage-3c W solve for one bucket's rows [Kb, R]."""
        if smooth_lam <= 0.0:
            wn, _ = cons_w.update(rows.astype(wb.dtype), gram3, wb, (),
                                  **solve_kw)
            return wn
        # temporal anchor: per-subject lam_k = smooth_lam * has_prev, folded
        # into the normal equations -> per-subject Gram, vmapped row solves
        lam_k = jnp.asarray(smooth_lam, wb.dtype) * pmaskb        # [Kb]
        M = rows.astype(wb.dtype) + lam_k[:, None] * prevb        # [Kb, R]
        eye = jnp.eye(R, dtype=wb.dtype)
        A = gram3.astype(wb.dtype)[None] + lam_k[:, None, None] * eye  # [Kb,R,R]

        def one(m, a, w0):
            x, _ = cons_w.update(m[None, :], a, w0[None, :], (), **solve_kw)
            return x[0]

        return jax.vmap(one)(M, A, prevb * pmaskb[:, None] +
                             wb * (1.0 - pmaskb)[:, None])

    # maintain the batch rows as a per-bucket tuple (the _w_rows layout)
    wbs = [jnp.take(w_init, b.subject_ids, axis=0) * b.subject_mask[:, None]
           for b in batch.buckets]
    Gs: List[jax.Array] = [None] * len(batch.buckets)
    for _ in range(inner_iters):
        Wt = tuple(wbs)
        for i, b in enumerate(batch.buckets):
            proj, _, _ = _procrustes_project(b, H, V, Wt, opts, i, be)
            G = be.ykv_bucket(b, proj, V)                 # [Kb, R, R]
            Gs[i] = G
            rows = be.mode3_bucket(b, proj, H, YkV=G)     # [Kb, R]
            prevb = jnp.take(w_prev, b.subject_ids, axis=0)
            pmaskb = jnp.take(prev_mask, b.subject_ids, axis=0) * b.subject_mask
            wbs[i] = _row_solve(rows, wbs[i], prevb, pmaskb) \
                * b.subject_mask[:, None]

    # per-subject residual at the final rows (Q from the last Procrustes —
    # the same staleness convention as the als_step fit)
    W_out = jnp.zeros((batch.n_subjects, R), opts.dtype)
    resid = jnp.zeros((batch.n_subjects,), opts.dtype)
    for b, wb, G in zip(batch.buckets, wbs, Gs):
        sq = b.sq_norms().astype(opts.dtype)
        cross = jnp.einsum("rl,krl,kl->k", H, G, wb).astype(opts.dtype)
        model = jnp.einsum("rl,rl,kr,kl->k", Phi, VtV, wb, wb).astype(opts.dtype)
        rb = (sq - 2.0 * cross + model) * b.subject_mask.astype(opts.dtype)
        W_out = W_out.at[b.subject_ids].add(
            wb.astype(opts.dtype) * b.subject_mask[:, None].astype(opts.dtype))
        resid = resid.at[b.subject_ids].add(rb)
    return W_out, resid


def reconstruct_uk(
    data: Bucketed, state: Parafac2State, opts: Parafac2Options
) -> Dict[int, np.ndarray]:
    """Assemble U_k = Q_k H per subject (host-side, for interpretation)."""
    out: Dict[int, np.ndarray] = {}
    for i, b in enumerate(data.buckets):
        _, _, Q = _procrustes_project(b, state.H, state.V, state.W, opts, i)
        Uk = np.asarray(jnp.einsum("kir,rl->kil", Q, state.H))
        sids = np.asarray(b.subject_ids)
        smask = np.asarray(b.subject_mask)
        rows = np.asarray(b.row_counts)
        for slot in range(b.kb):
            if smask[slot] > 0:
                out[int(sids[slot])] = Uk[slot, : rows[slot], :]
    return out
