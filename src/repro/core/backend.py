"""Pluggable MTTKRP compute backends for the SPARTan ALS hot loop.

The ALS algebra (``core/parafac2.py``) never touches a kernel directly: it
asks an :class:`MttkrpBackend` for the per-bucket SPARTan contractions
and the shared stages. Four implementations:

``jnp``
    The pure-jnp math in :mod:`repro.core.spartan` — the reference path, exact
    in f64, used by the algebra tests.
``pallas``
    Dispatches through :mod:`repro.kernels.ops` — Mosaic kernels on TPU,
    ``interpret=True`` emulation elsewhere (a correctness tool, not a fast
    path off-TPU). Outputs are f32 accumulations; f64 inputs are demoted.
``scoo``
    The O(nnz) sparse route (:class:`SparseBackend`): on SCOO buckets
    (:class:`repro.core.irregular.SparseBucket`) every stage contracts the
    flat COO triplets directly via :mod:`repro.kernels.scoo` and the
    projected slices Y_k are NEVER materialized — ``project_bucket`` carries
    Q itself. CC buckets delegate to ``jnp``.
``fused``
    The fused ALS megakernel route (:mod:`repro.kernels.fused`): per CC
    bucket per iteration, four fused launches stream each subject's slab
    through VMEM with double-buffered DMA and write only the small
    [I,R]/[R,R]/[C,R] results — the projected Y_k is NEVER materialized
    (``project_bucket`` carries Q, like the SCOO route). SCOO buckets
    delegate to ``scoo``. ``dispatch_tally`` measures the collapse from the
    staged path's five streaming stage launches to four (four, not one,
    because the Procrustes eigendecomposition and the H-/V-solves are global
    sync points — see kernels/fused.py).
``auto``
    Per-bucket dispatch: SCOO buckets take the ``scoo`` native route; CC
    buckets go to ``fused`` on TPU for kernel-friendly geometry (f32/bf16
    with R a multiple of 8 and C a multiple of 128 — the MXU sublane/lane
    quanta the ``col_align=128`` bucketizer default produces), ``pallas``
    for the array-level CC contractions at the same geometry, and ``jnp``
    everywhere else, including all CPU/GPU runs.

Every backend also takes a ``precision`` knob ("f32" | "bf16" | "f16",
``Parafac2Options.precision`` / ``get_backend(name, precision)``): below
f32, the large streamed operands (the vals slab, Vg, and the staged Y_k)
are cast half-width before each contraction while every dot still
accumulates in f32 via ``kernels.common.accum_dtype`` — bf16 x bf16
products are exact in f32 (8-bit mantissas), so only the cast of the
inputs loses bits, and the streamed HBM bytes halve. ``precision="f32"``
is bitwise-identical to the historical paths.

Two API levels. The *bucket-level* stages (``xkv_bucket`` /
``project_bucket`` / ``ykv_bucket`` / ``mode{1,2,3}_bucket``) are what
``als_step`` calls: they take the bucket itself, so a backend can pick a
representation per device format — this is where the CC-vs-SCOO split lives,
and why a mixed-format ``Bucketed`` (``bucketize(format="auto")``) runs
every engine/backend/constraint combination unchanged. The *array-level*
methods (``mode1`` / ``mode2_compact`` / ``mode3`` / ``ykv`` on explicit
Yc/Vg arrays) remain the CC contraction contract the kernel parity tests
and micro benchmarks exercise.

The backend layer is also the single place the ``"subjects"`` logical-axis
sharding constraints (:func:`repro.dist.sharding.shard`) are applied: every
Kb-leading input and output passes through :meth:`MttkrpBackend.shard_subjects`
uniformly, instead of ad-hoc ``shard`` calls scattered through the math. The
memory-bound :meth:`MttkrpBackend.mode2_scatter` (XLA scatter-add into
J-space) is a shared stage every backend reuses; :meth:`MttkrpBackend.ykv`
(the Y_k V product the ALS step computes once per bucket and feeds to the
mode-1/mode-3 reuse entry points and the fit) dispatches per backend like
the modes do.

Select via ``Parafac2Options(backend=...)`` or ``--backend`` on the launchers
and benchmarks. See docs/ARCHITECTURE.md (stage 4½ and the SCOO stage).
"""
from __future__ import annotations

import abc
import collections
import contextlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import spartan
from repro.core.irregular import SparseBucket
from repro.dist.sharding import shard
from repro.kernels.common import (PRECISIONS, accum_dtype, compute_cast,
                                  fold_subject_mask)

__all__ = [
    "MttkrpBackend",
    "JnpBackend",
    "PallasBackend",
    "SparseBackend",
    "FusedBackend",
    "AutoBackend",
    "BACKENDS",
    "get_backend",
    "dispatch_tally",
]


# ---------------------------------------------------------------------------
# Dispatch tally: how many per-bucket stage launches stream large operands
# ---------------------------------------------------------------------------

_TALLY: Optional[collections.Counter] = None


@contextlib.contextmanager
def dispatch_tally():
    """Count the per-bucket backend stage launches that stream I-/C-sized
    operands (the slab, XkV/Q, or Yc) — the launches the fused megakernel
    route collapses. Stages that only touch [Kb,R,R]-and-smaller tiles
    (mode-1/mode-3 from a cached YkV) are not counted.

    Counting happens when the backend methods RUN (eagerly or at jit trace
    time), so wrap one untraced/tracing ``als_step`` evaluation::

        with dispatch_tally() as t:
            jax.eval_shape(lambda s: als_step(data, s, opts), state)
        per_bucket = sum(t.values()) / len(data.buckets)

    The staged CC path tallies 5 per bucket per iteration (procrustes_b,
    project, mode1, mode2, ykv); the fused route tallies 4 — the standalone
    projection pass disappears (``project_bucket`` carries Q).
    """
    global _TALLY
    prev, _TALLY = _TALLY, collections.Counter()
    try:
        yield _TALLY
    finally:
        _TALLY = prev


def _tick(stage: str) -> None:
    if _TALLY is not None:
        _TALLY[stage] += 1


class MttkrpBackend(abc.ABC):
    """The three SPARTan MTTKRP contractions, per bucket.

    Per-bucket shapes (Kb subjects, C kept-cols padded, rank R):
      Yc [Kb, R, C] compressed slices; Vg [Kb, C, R] gathered V rows;
      Wb [Kb, R] W rows; masks 1.0 = real, 0.0 = padding.
    Subclasses implement ``_mode1`` / ``_mode2_compact`` / ``_mode3``; the
    public methods add the uniform subject-axis sharding constraints.

    ``precision`` ("f32" default) below f32 stages the large streamed
    operands half-width via :func:`repro.kernels.common.compute_cast` while
    accumulating f32 (``accum_dtype``); "f32" keeps every path bitwise
    identical to the unconfigured backend.
    """

    name: str = "?"

    def __init__(self, precision: str = "f32"):
        if precision not in PRECISIONS:
            raise ValueError(
                f"unknown compute precision {precision!r}; "
                f"choose from {PRECISIONS}")
        self.precision = precision

    def _pc(self, x: Optional[jax.Array]) -> Optional[jax.Array]:
        """Cast a streamed operand to the compute precision (identity at
        "f32" — the configured-precision paths stay bitwise otherwise)."""
        return compute_cast(x, self.precision)

    # -- uniform sharding ---------------------------------------------------
    @staticmethod
    def shard_subjects(x: Optional[jax.Array]) -> Optional[jax.Array]:
        """Constrain a Kb-leading array onto the "subjects" logical axis
        (no-op outside a mesh)."""
        if x is None:
            return None
        return shard(x, ("subjects",) + (None,) * (x.ndim - 1))

    # -- shared stages ------------------------------------------------------
    def ykv(self, Yc: jax.Array, Vg: jax.Array) -> jax.Array:
        """Y_k V [Kb, R, R] — the product the mode-1/mode-3 reuse paths and
        the fit computation share; the ALS step computes it once per bucket."""
        return jnp.einsum("krc,kcl->krl", spartan._f(Yc), spartan._f(Vg))

    mode2_scatter = staticmethod(spartan.mode2_scatter)

    # -- bucket-level stages (the als_step contract) ------------------------
    # These take the bucket itself so an implementation can pick a per-format
    # representation. The dense route below (CC buckets, and SCOO buckets
    # under the jnp/pallas backends, whose SparseBucket.project is an O(nnz)
    # segment-sum into the same compact Yc layout) materializes Yc [Kb,R,C];
    # SparseBackend overrides carry Q instead and never build Yc.

    def xkv_bucket(self, b, V: jax.Array,
                   Vg: Optional[jax.Array] = None) -> jax.Array:
        """X_k V [Kb, I_pad, R] — the Procrustes-step input."""
        if self.precision != "f32" and not isinstance(b, SparseBucket):
            Vg = b.gather_v(V) if Vg is None else Vg
            out = jnp.einsum(
                "kic,kcr->kir", self._pc(b.vals), self._pc(Vg),
                preferred_element_type=accum_dtype(b.vals))
            return self.shard_subjects(out)
        return self.shard_subjects(b.xk_times_v(V, Vg))

    def procrustes_b_bucket(self, b, H: jax.Array, Wb: jax.Array,
                            V: jax.Array, Vg: Optional[jax.Array] = None):
        """Step-1 pair for one bucket: (XkV [Kb,I,R], B [Kb,I,R]) with
        B_k = (X_k V * w_k) H^T — the Procrustes input. The staged default
        is xkv + a small einsum; the fused backend forms both in one slab
        pass."""
        _tick("procrustes_b")
        XkV = self.xkv_bucket(b, V, Vg)
        B = jnp.einsum("kir,lr->kil", XkV * Wb[:, None, :], H)
        return XkV, B

    def mode1_xkv_bucket(self, b, Q: jax.Array, XkV: jax.Array,
                         Wb: jax.Array) -> jax.Array:
        """Partial M1 [R,R] via the mode-1 reuse identity
        Y_k V = Q_k^T (X_k V) — no slab pass, but the [Kb,I,R] operands
        stream. The fused backend reduces M1 in the same dispatch that
        forms the per-subject YkV, which is never written back."""
        _tick("mode1")
        YkV = jnp.einsum("kir,kil->krl", Q, XkV)
        return self.mode1(None, None, Wb, b.subject_mask, YkV=YkV)

    def sketch_bucket(self, b, Omega: jax.Array,
                      Og: Optional[jax.Array] = None) -> jax.Array:
        """Y_k = X_k Ω [Kb, I_pad, S] — the randomized range-finder sketch
        (:mod:`repro.core.compress`). Same contraction as ``xkv_bucket`` with
        a wider right factor: tall-skinny MXU matmuls on CC buckets, O(nnz*S)
        segment-sums on SCOO buckets (the sketch never densifies them)."""
        from repro.kernels import sketch as _sketch

        return self.shard_subjects(_sketch.sketch_bucket(b, Omega, Og))

    def project_bucket(self, b, Q: jax.Array):
        """Per-bucket projected representation consumed by the *_bucket
        stages below: the compact Yc [Kb, R, C] on the dense route (staged
        half-width when ``precision`` is below f32)."""
        _tick("project")
        if self.precision != "f32" and not isinstance(b, SparseBucket):
            Yc = jnp.einsum(
                "kir,kic->krc", self._pc(Q), self._pc(b.vals),
                preferred_element_type=accum_dtype(b.vals))
            return self.shard_subjects(self._pc(Yc))
        return self.shard_subjects(b.project(Q))

    def ykv_bucket(self, b, proj, V: jax.Array) -> jax.Array:
        """Y_k V [Kb, R, R] for factor ``V`` (the W-update/fit G product)."""
        _tick("ykv")
        return self.ykv(proj, self._pc(b.gather_v(V)))

    def mode1_bucket(self, b, proj, Wb: jax.Array,
                     V: Optional[jax.Array] = None, *, YkV=None) -> jax.Array:
        if YkV is None:
            _tick("mode1")
        Vg = None if YkV is not None else self._pc(b.gather_v(V))
        return self.mode1(proj, Vg, Wb, b.subject_mask, YkV=YkV)

    def mode2_bucket(self, b, proj, H: jax.Array, Wb: jax.Array) -> jax.Array:
        _tick("mode2")
        return self.mode2_compact(proj, H, Wb, b.col_mask, b.subject_mask)

    def mode3_bucket(self, b, proj, H: jax.Array,
                     V: Optional[jax.Array] = None, *, YkV=None) -> jax.Array:
        if YkV is None:
            _tick("mode3")
        Vg = None if YkV is not None else self._pc(b.gather_v(V))
        return self.mode3(proj, Vg, H, b.subject_mask, YkV=YkV)

    # -- per-bucket contractions --------------------------------------------
    def mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None) -> jax.Array:
        """Partial M1 [R, R] = sum_k (Y_k V) * W(k,:). With ``YkV`` cached
        (mode1_reuse), Vg may be None and the gather+matmul is skipped."""
        Yc, Vg, Wb, subject_mask, YkV = map(
            self.shard_subjects, (Yc, Vg, Wb, subject_mask, YkV))
        return self._mode1(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def mode2_compact(self, Yc, H, Wb, col_mask, subject_mask) -> jax.Array:
        """Compact A [Kb, C, R] = (Y_k^T H) * W(k,:); masked rows are 0."""
        Yc, Wb, col_mask, subject_mask = map(
            self.shard_subjects, (Yc, Wb, col_mask, subject_mask))
        return self.shard_subjects(
            self._mode2_compact(Yc, H, Wb, col_mask, subject_mask))

    def mode3(self, Yc, Vg, H, subject_mask, *, YkV=None) -> jax.Array:
        """Per-subject M3 rows [Kb, R] = coldot(H, Y_k V)."""
        Yc, Vg, subject_mask, YkV = map(
            self.shard_subjects, (Yc, Vg, subject_mask, YkV))
        return self.shard_subjects(self._mode3(Yc, Vg, H, subject_mask, YkV=YkV))

    @abc.abstractmethod
    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None) -> jax.Array: ...

    @abc.abstractmethod
    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask) -> jax.Array: ...

    @abc.abstractmethod
    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None) -> jax.Array: ...

    # -- whole-tensor helpers (the one callsite shape per mode) -------------
    def mttkrp_mode1(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     V: jax.Array, W: jax.Array) -> jax.Array:
        """M1 [R, R] over all buckets, with W global [K, R]."""
        return sum(
            self.mode1(Yc, b.gather_v(V), jnp.take(W, b.subject_ids, 0),
                       b.subject_mask)
            for b, Yc in zip(buckets, Ycs))

    def mttkrp_mode2(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     H: jax.Array, W: jax.Array, J: int) -> jax.Array:
        """M2 [J, R]: compact compute stage per bucket + shared scatter."""
        M2 = jnp.zeros((J, H.shape[0]), H.dtype)
        for b, Yc in zip(buckets, Ycs):
            A = self.mode2_compact(Yc, H, jnp.take(W, b.subject_ids, 0),
                                   b.col_mask, b.subject_mask)
            M2 = M2 + self.mode2_scatter(A, b.cols, J).astype(M2.dtype)
        return M2

    def mttkrp_mode3(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     V: jax.Array, H: jax.Array, K: int) -> jax.Array:
        """M3 [K, R]: per-subject rows scattered to global subject ids."""
        M3 = jnp.zeros((K, H.shape[0]), H.dtype)
        for b, Yc in zip(buckets, Ycs):
            rows = self.mode3(Yc, b.gather_v(V), H, b.subject_mask)
            M3 = M3.at[b.subject_ids].add(rows.astype(M3.dtype))
        return M3


class JnpBackend(MttkrpBackend):
    """The :mod:`repro.core.spartan` math — today's numerics, exactly."""

    name = "jnp"

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        return spartan.mode1_bucket(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return spartan.mode2_bucket_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        return spartan.mode3_bucket(Yc, Vg, H, subject_mask, YkV=YkV)


class PallasBackend(MttkrpBackend):
    """Routes through the Pallas kernels (:mod:`repro.kernels.ops`).

    Mosaic on TPU; interpret mode elsewhere. Kernel accumulators are f32, so
    outputs come back f32 regardless of input dtype; f64 inputs are demoted
    to f32 on the way in (use ``jnp`` for f64 algebra).
    """

    name = "pallas"

    @staticmethod
    def _k32(x: Optional[jax.Array]) -> Optional[jax.Array]:
        if x is not None and x.dtype == jnp.float64:
            return x.astype(jnp.float32)
        return x

    def ykv(self, Yc, Vg):
        from repro.kernels import ops
        return ops.ykv(self._k32(Yc), self._k32(Vg))

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        from repro.kernels import ops
        return ops.mttkrp_mode1(
            self._k32(Yc), self._k32(Vg), self._k32(Wb),
            subject_mask=self._k32(subject_mask), YkV=self._k32(YkV))

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        from repro.kernels import ops
        return ops.mttkrp_mode2_compact(
            self._k32(Yc), self._k32(H), self._k32(Wb),
            col_mask=self._k32(col_mask), subject_mask=self._k32(subject_mask))

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        from repro.kernels import ops
        return ops.mttkrp_mode3(
            self._k32(Yc), self._k32(Vg), self._k32(H),
            subject_mask=self._k32(subject_mask), YkV=self._k32(YkV))

    # SCOO buckets: the Pallas one-hot/scalar-prefetch segment-sum kernels
    # produce X_k V and the compact Yc (kernels/scoo.py); the per-stage CC
    # kernels then consume Yc exactly as for a CC bucket.
    def xkv_bucket(self, b, V, Vg=None):
        if isinstance(b, SparseBucket):
            from repro.kernels import scoo
            Vg = b.gather_v(V) if Vg is None else Vg
            return self.shard_subjects(scoo.xk_times_v(
                self._pc(self._k32(b.vals)), b.rows, b.lcols,
                self._pc(self._k32(Vg)), b.i_pad,
                nnz_counts=b.nnz_counts, use_pallas=True))
        return super().xkv_bucket(b, V, Vg)

    def project_bucket(self, b, Q):
        if isinstance(b, SparseBucket):
            from repro.kernels import scoo
            _tick("project")
            return self.shard_subjects(scoo.project(
                self._pc(self._k32(b.vals)), b.rows, b.lcols,
                self._k32(Q), b.c_pad,
                nnz_counts=b.nnz_counts, use_pallas=True))
        return super().project_bucket(b, Q)


class SparseBackend(MttkrpBackend):
    """The O(nnz) SCOO-native route (:mod:`repro.kernels.scoo`).

    On SCOO buckets the projected slices are never materialized:
    ``project_bucket`` returns Q itself and every downstream stage contracts
    the flat COO triplets directly (gather + segment-sum / outer-product
    accumulation). CC buckets — present in a mixed-format Bucketed from
    ``bucketize(format="auto")`` — delegate to the inner dense backend
    (``jnp`` by default), as do the array-level CC contraction methods.
    """

    name = "scoo"

    def __init__(self, inner: Optional[MttkrpBackend] = None,
                 precision: str = "f32"):
        super().__init__(precision)
        self._inner = inner if inner is not None else JnpBackend(precision)

    # -- array-level CC contract: delegate wholesale ------------------------
    def ykv(self, Yc, Vg):
        return self._inner.ykv(Yc, Vg)

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        return self._inner._mode1(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return self._inner._mode2_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        return self._inner._mode3(Yc, Vg, H, subject_mask, YkV=YkV)

    # -- bucket-level stages: SCOO-native, Yc-free --------------------------
    def _ykv_native(self, b: SparseBucket, Q, V):
        from repro.kernels import scoo
        return scoo.ykv_scoo(self._pc(b.vals), b.rows, b.lcols,
                             self.shard_subjects(Q),
                             self._pc(b.gather_v(V)))

    def project_bucket(self, b, Q):
        if not isinstance(b, SparseBucket):
            return self._inner.project_bucket(b, Q)
        return self.shard_subjects(Q)   # carry Q; Yc is never built

    def ykv_bucket(self, b, proj, V):
        if not isinstance(b, SparseBucket):
            return self._inner.ykv_bucket(b, proj, V)
        _tick("ykv")
        return self._ykv_native(b, proj, V)

    def mode1_bucket(self, b, proj, Wb, V=None, *, YkV=None):
        if not isinstance(b, SparseBucket):
            return self._inner.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        if YkV is None:
            _tick("mode1")
            YkV = self._ykv_native(b, proj, V)
        # YkV in hand, the remaining Hadamard + subject reduction is the
        # shared R x R algebra (uniform shard constraints included)
        return self.mode1(None, None, Wb, b.subject_mask, YkV=YkV)

    def mode2_bucket(self, b, proj, H, Wb):
        if not isinstance(b, SparseBucket):
            return self._inner.mode2_bucket(b, proj, H, Wb)
        from repro.kernels import scoo
        _tick("mode2")
        Q, Wb, col_mask, smask = map(
            self.shard_subjects, (proj, Wb, b.col_mask, b.subject_mask))
        return self.shard_subjects(scoo.mode2_compact_scoo(
            self._pc(b.vals), b.rows, b.lcols, Q, H, Wb, col_mask, smask,
            cperm=b.cperm, col_ends=b.col_ends))

    def mode3_bucket(self, b, proj, H, V=None, *, YkV=None):
        if not isinstance(b, SparseBucket):
            return self._inner.mode3_bucket(b, proj, H, V, YkV=YkV)
        if YkV is None:
            _tick("mode3")
            YkV = self._ykv_native(b, proj, V)
        return self.mode3(None, None, H, b.subject_mask, YkV=YkV)


class FusedBackend(MttkrpBackend):
    """The fused ALS megakernel route (:mod:`repro.kernels.fused`).

    On CC buckets the four per-iteration streaming launches each pull the
    subject's [I_pad, C_pad] slab through VMEM with double-buffered DMA and
    write only the small results back; the projected slices are never
    materialized — ``project_bucket`` carries Q itself, exactly like the
    SCOO-native route (so the ``als_step`` contract is unchanged). SCOO
    buckets delegate wholesale to :class:`SparseBackend`; the array-level
    CC contraction methods (explicit Yc in hand) delegate to ``jnp``.

    Unlike :class:`PallasBackend` there is no f64 demotion: f64 inputs
    accumulate f64 (``accum_dtype``), which the interpret-mode parity tests
    rely on. Real TPUs reject f64 Mosaic kernels — ``AutoBackend._fused_ok``
    gates the automatic route to f32/bf16 there.
    """

    name = "fused"

    def __init__(self, precision: str = "f32"):
        super().__init__(precision)
        self._jnp = JnpBackend(precision)
        self._sparse = SparseBackend(inner=self._jnp, precision=precision)

    @staticmethod
    def _interp() -> bool:
        from repro.kernels import fused
        return fused._interpret()

    # -- array-level CC contract: delegate to jnp ---------------------------
    def ykv(self, Yc, Vg):
        return self._jnp.ykv(Yc, Vg)

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        return self._jnp._mode1(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return self._jnp._mode2_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        return self._jnp._mode3(Yc, Vg, H, subject_mask, YkV=YkV)

    # -- bucket-level stages: the four fused launches -----------------------
    def procrustes_b_bucket(self, b, H, Wb, V, Vg=None):
        if isinstance(b, SparseBucket):
            return self._sparse.procrustes_b_bucket(b, H, Wb, V, Vg)
        from repro.kernels import fused
        _tick("procrustes_b")
        Vg = b.gather_v(V) if Vg is None else Vg
        XkV, B = fused.fused_procrustes_b(
            self._pc(b.vals), self._pc(Vg), Wb, H, interpret=self._interp())
        return self.shard_subjects(XkV), self.shard_subjects(B)

    def project_bucket(self, b, Q):
        if isinstance(b, SparseBucket):
            return self._sparse.project_bucket(b, Q)
        return self.shard_subjects(Q)   # carry Q; Yc is never built

    def mode1_xkv_bucket(self, b, Q, XkV, Wb):
        from repro.kernels import fused
        _tick("mode1")
        Wb = fold_subject_mask(Wb, b.subject_mask)
        return fused.fused_mode1_xkv(Q, XkV, Wb, interpret=self._interp())

    def ykv_bucket(self, b, proj, V):
        if isinstance(b, SparseBucket):
            return self._sparse.ykv_bucket(b, proj, V)
        from repro.kernels import fused
        _tick("ykv")
        return self.shard_subjects(fused.fused_ykv(
            self._pc(b.vals), proj, self._pc(b.gather_v(V)),
            interpret=self._interp()))

    def mode1_bucket(self, b, proj, Wb, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        if YkV is None:
            YkV = self.ykv_bucket(b, proj, V)
        return self.mode1(None, None, Wb, b.subject_mask, YkV=YkV)

    def mode2_bucket(self, b, proj, H, Wb):
        if isinstance(b, SparseBucket):
            return self._sparse.mode2_bucket(b, proj, H, Wb)
        from repro.kernels import fused
        _tick("mode2")
        Q, Wb_m, cm = map(self.shard_subjects,
                          (proj, fold_subject_mask(Wb, b.subject_mask),
                           b.col_mask))
        return self.shard_subjects(fused.fused_mode2_compact(
            self._pc(b.vals), Q, H, Wb_m, cm, interpret=self._interp()))

    def mode3_bucket(self, b, proj, H, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode3_bucket(b, proj, H, V, YkV=YkV)
        if YkV is None:
            YkV = self.ykv_bucket(b, proj, V)
        # YkV in hand, mode-3 is the shared [R,R] coldot — no slab pass left
        return self.mode3(None, None, H, b.subject_mask, YkV=YkV)


class AutoBackend(MttkrpBackend):
    """Per-platform, per-bucket dispatch between jnp, pallas, and scoo.

    The decision is made at trace time from static bucket types and
    shapes/dtypes, so under jit each bucket compiles against exactly one
    implementation. SCOO buckets always take the O(nnz) native route
    (:class:`SparseBackend` — the format was chosen *because* the bucket is
    sparse, so the dense kernels are never the right answer for it); CC
    buckets the kernels handle poorly (odd R/C, f64, non-TPU platforms)
    fall back to jnp.
    """

    name = "auto"

    def __init__(self, precision: str = "f32"):
        super().__init__(precision)
        self._jnp = JnpBackend(precision)
        self._pallas = PallasBackend(precision)
        self._sparse = SparseBackend(inner=self._jnp, precision=precision)
        self._fused = FusedBackend(precision)

    def _fused_ok(self, b, R: int) -> bool:
        """Route a CC bucket through the fused megakernel stages: TPU,
        f32/bf16 (Mosaic rejects f64), and MXU-quantized geometry. The
        predicate is a function of static bucket shape/dtype and R only, so
        every stage of an iteration makes the SAME call — the projected
        representation (Q on the fused route, Yc on the staged one) must
        stay coherent across ``project_bucket`` and its consumers."""
        return (not isinstance(b, SparseBucket)
                and jax.default_backend() == "tpu"
                and b.vals.dtype != jnp.float64
                and R % 8 == 0 and b.c_pad % 128 == 0)

    # -- bucket-level: SCOO -> native sparse; friendly CC on TPU -> fused ---
    def xkv_bucket(self, b, V, Vg=None):
        if isinstance(b, SparseBucket):
            return self._sparse.xkv_bucket(b, V, Vg)
        return super().xkv_bucket(b, V, Vg)

    def procrustes_b_bucket(self, b, H, Wb, V, Vg=None):
        if isinstance(b, SparseBucket):
            return self._sparse.procrustes_b_bucket(b, H, Wb, V, Vg)
        if self._fused_ok(b, H.shape[0]):
            return self._fused.procrustes_b_bucket(b, H, Wb, V, Vg)
        return super().procrustes_b_bucket(b, H, Wb, V, Vg)

    def mode1_xkv_bucket(self, b, Q, XkV, Wb):
        if not isinstance(b, SparseBucket) and self._fused_ok(b, Q.shape[-1]):
            return self._fused.mode1_xkv_bucket(b, Q, XkV, Wb)
        return super().mode1_xkv_bucket(b, Q, XkV, Wb)

    def project_bucket(self, b, Q):
        if isinstance(b, SparseBucket):
            return self._sparse.project_bucket(b, Q)
        if self._fused_ok(b, Q.shape[-1]):
            return self._fused.project_bucket(b, Q)
        return super().project_bucket(b, Q)

    def ykv_bucket(self, b, proj, V):
        if isinstance(b, SparseBucket):
            return self._sparse.ykv_bucket(b, proj, V)
        if self._fused_ok(b, V.shape[-1]):
            return self._fused.ykv_bucket(b, proj, V)
        return super().ykv_bucket(b, proj, V)

    def mode1_bucket(self, b, proj, Wb, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        if self._fused_ok(b, Wb.shape[-1]):
            return self._fused.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        return super().mode1_bucket(b, proj, Wb, V, YkV=YkV)

    def mode2_bucket(self, b, proj, H, Wb):
        if isinstance(b, SparseBucket):
            return self._sparse.mode2_bucket(b, proj, H, Wb)
        if self._fused_ok(b, H.shape[0]):
            return self._fused.mode2_bucket(b, proj, H, Wb)
        return super().mode2_bucket(b, proj, H, Wb)

    def mode3_bucket(self, b, proj, H, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode3_bucket(b, proj, H, V, YkV=YkV)
        if self._fused_ok(b, H.shape[0]):
            return self._fused.mode3_bucket(b, proj, H, V, YkV=YkV)
        return super().mode3_bucket(b, proj, H, V, YkV=YkV)

    @staticmethod
    def _platform_ok(probe: Optional[jax.Array]) -> bool:
        return (probe is not None and jax.default_backend() == "tpu"
                and probe.dtype != jnp.float64)

    @classmethod
    def _kernel_friendly(cls, probe: Optional[jax.Array]) -> bool:
        """Full C-contraction kernels: want R on the sublane quantum and the
        kept-column count C on the lane quantum (col_align=128 default)."""
        if not cls._platform_ok(probe):
            return False
        R, C = probe.shape[-2], probe.shape[-1]
        return R % 8 == 0 and C % 128 == 0

    @classmethod
    def _reuse_friendly(cls, YkV: Optional[jax.Array]) -> bool:
        """YkV-cached kernels only touch [Kb,R,R] tiles (VPU reductions), so
        only the sublane quantum matters — Mosaic lane-pads the small R."""
        if not cls._platform_ok(YkV):
            return False
        return YkV.shape[-1] % 8 == 0

    def _pick(self, probe, *, reuse: bool = False) -> MttkrpBackend:
        ok = self._reuse_friendly(probe) if reuse else self._kernel_friendly(probe)
        return self._pallas if ok else self._jnp

    def ykv(self, Yc, Vg):
        return self._pick(Yc).ykv(Yc, Vg)

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        if YkV is not None:
            return self._pick(YkV, reuse=True)._mode1(
                Yc, Vg, Wb, subject_mask, YkV=YkV)
        return self._pick(Yc)._mode1(Yc, Vg, Wb, subject_mask, YkV=None)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return self._pick(Yc)._mode2_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        if YkV is not None:
            return self._pick(YkV, reuse=True)._mode3(
                Yc, Vg, H, subject_mask, YkV=YkV)
        return self._pick(Yc)._mode3(Yc, Vg, H, subject_mask, YkV=None)


BACKENDS = {"jnp": JnpBackend(), "pallas": PallasBackend(),
            "scoo": SparseBackend(), "fused": FusedBackend(),
            "auto": AutoBackend()}

# configured (non-f32 precision) instances, cached per (name, precision) so
# repeated get_backend calls hand jit the SAME backend object (stable tracing)
_CONFIGURED: Dict[Tuple[str, str], MttkrpBackend] = {}


def get_backend(name, precision: Optional[str] = None) -> MttkrpBackend:
    """Resolve a backend by name ("jnp" | "pallas" | "scoo" | "fused" |
    "auto") or pass an :class:`MttkrpBackend` instance through unchanged.
    ``precision`` (None/"f32" default) returns a configured instance that
    stages streamed operands at that compute precision (see the class docs);
    the f32 singletons in ``BACKENDS`` are untouched."""
    if isinstance(name, MttkrpBackend):
        return name
    if name not in BACKENDS:
        raise ValueError(
            f"unknown MTTKRP backend {name!r}; choose from {sorted(BACKENDS)}")
    if precision is None or precision == "f32":
        return BACKENDS[name]
    key = (name, precision)
    if key not in _CONFIGURED:
        _CONFIGURED[key] = type(BACKENDS[name])(precision=precision)
    return _CONFIGURED[key]
