"""Pluggable MTTKRP compute backends for the SPARTan ALS hot loop.

The ALS algebra (``core/parafac2.py``) never touches a kernel directly: it
asks an :class:`MttkrpBackend` for the per-bucket SPARTan contractions
and the shared stages. Four implementations:

``jnp``
    The pure-jnp math in :mod:`repro.core.spartan` — the reference path, exact
    in f64, used by the algebra tests.
``pallas``
    Dispatches through :mod:`repro.kernels.ops` — Mosaic kernels on TPU,
    ``interpret=True`` emulation elsewhere (a correctness tool, not a fast
    path off-TPU). Outputs are f32 accumulations; f64 inputs are demoted.
``scoo``
    The O(nnz) sparse route (:class:`SparseBackend`): on SCOO buckets
    (:class:`repro.core.irregular.SparseBucket`) every stage contracts the
    flat COO triplets directly via :mod:`repro.kernels.scoo` and the
    projected slices Y_k are NEVER materialized — ``project_bucket`` carries
    Q itself. CC buckets delegate to ``jnp``.
``auto``
    Per-bucket dispatch: SCOO buckets take the ``scoo`` native route; CC
    buckets go to ``pallas`` on TPU for kernel-friendly geometry (f32/bf16
    with R a multiple of 8 and C a multiple of 128 — the MXU sublane/lane
    quanta the ``col_align=128`` bucketizer default produces) and ``jnp``
    everywhere else, including all CPU/GPU runs.

Two API levels. The *bucket-level* stages (``xkv_bucket`` /
``project_bucket`` / ``ykv_bucket`` / ``mode{1,2,3}_bucket``) are what
``als_step`` calls: they take the bucket itself, so a backend can pick a
representation per device format — this is where the CC-vs-SCOO split lives,
and why a mixed-format ``Bucketed`` (``bucketize(format="auto")``) runs
every engine/backend/constraint combination unchanged. The *array-level*
methods (``mode1`` / ``mode2_compact`` / ``mode3`` / ``ykv`` on explicit
Yc/Vg arrays) remain the CC contraction contract the kernel parity tests
and micro benchmarks exercise.

The backend layer is also the single place the ``"subjects"`` logical-axis
sharding constraints (:func:`repro.dist.sharding.shard`) are applied: every
Kb-leading input and output passes through :meth:`MttkrpBackend.shard_subjects`
uniformly, instead of ad-hoc ``shard`` calls scattered through the math. The
memory-bound :meth:`MttkrpBackend.mode2_scatter` (XLA scatter-add into
J-space) is a shared stage every backend reuses; :meth:`MttkrpBackend.ykv`
(the Y_k V product the ALS step computes once per bucket and feeds to the
mode-1/mode-3 reuse entry points and the fit) dispatches per backend like
the modes do.

Select via ``Parafac2Options(backend=...)`` or ``--backend`` on the launchers
and benchmarks. See docs/ARCHITECTURE.md (stage 4½ and the SCOO stage).
"""
from __future__ import annotations

import abc
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import spartan
from repro.core.irregular import SparseBucket
from repro.dist.sharding import shard

__all__ = [
    "MttkrpBackend",
    "JnpBackend",
    "PallasBackend",
    "SparseBackend",
    "AutoBackend",
    "BACKENDS",
    "get_backend",
]


class MttkrpBackend(abc.ABC):
    """The three SPARTan MTTKRP contractions, per bucket.

    Per-bucket shapes (Kb subjects, C kept-cols padded, rank R):
      Yc [Kb, R, C] compressed slices; Vg [Kb, C, R] gathered V rows;
      Wb [Kb, R] W rows; masks 1.0 = real, 0.0 = padding.
    Subclasses implement ``_mode1`` / ``_mode2_compact`` / ``_mode3``; the
    public methods add the uniform subject-axis sharding constraints.
    """

    name: str = "?"

    # -- uniform sharding ---------------------------------------------------
    @staticmethod
    def shard_subjects(x: Optional[jax.Array]) -> Optional[jax.Array]:
        """Constrain a Kb-leading array onto the "subjects" logical axis
        (no-op outside a mesh)."""
        if x is None:
            return None
        return shard(x, ("subjects",) + (None,) * (x.ndim - 1))

    # -- shared stages ------------------------------------------------------
    def ykv(self, Yc: jax.Array, Vg: jax.Array) -> jax.Array:
        """Y_k V [Kb, R, R] — the product the mode-1/mode-3 reuse paths and
        the fit computation share; the ALS step computes it once per bucket."""
        return jnp.einsum("krc,kcl->krl", spartan._f(Yc), spartan._f(Vg))

    mode2_scatter = staticmethod(spartan.mode2_scatter)

    # -- bucket-level stages (the als_step contract) ------------------------
    # These take the bucket itself so an implementation can pick a per-format
    # representation. The dense route below (CC buckets, and SCOO buckets
    # under the jnp/pallas backends, whose SparseBucket.project is an O(nnz)
    # segment-sum into the same compact Yc layout) materializes Yc [Kb,R,C];
    # SparseBackend overrides carry Q instead and never build Yc.

    def xkv_bucket(self, b, V: jax.Array,
                   Vg: Optional[jax.Array] = None) -> jax.Array:
        """X_k V [Kb, I_pad, R] — the Procrustes-step input."""
        return self.shard_subjects(b.xk_times_v(V, Vg))

    def sketch_bucket(self, b, Omega: jax.Array,
                      Og: Optional[jax.Array] = None) -> jax.Array:
        """Y_k = X_k Ω [Kb, I_pad, S] — the randomized range-finder sketch
        (:mod:`repro.core.compress`). Same contraction as ``xkv_bucket`` with
        a wider right factor: tall-skinny MXU matmuls on CC buckets, O(nnz*S)
        segment-sums on SCOO buckets (the sketch never densifies them)."""
        from repro.kernels import sketch as _sketch

        return self.shard_subjects(_sketch.sketch_bucket(b, Omega, Og))

    def project_bucket(self, b, Q: jax.Array):
        """Per-bucket projected representation consumed by the *_bucket
        stages below: the compact Yc [Kb, R, C] on the dense route."""
        return self.shard_subjects(b.project(Q))

    def ykv_bucket(self, b, proj, V: jax.Array) -> jax.Array:
        """Y_k V [Kb, R, R] for factor ``V`` (the W-update/fit G product)."""
        return self.ykv(proj, b.gather_v(V))

    def mode1_bucket(self, b, proj, Wb: jax.Array,
                     V: Optional[jax.Array] = None, *, YkV=None) -> jax.Array:
        Vg = None if YkV is not None else b.gather_v(V)
        return self.mode1(proj, Vg, Wb, b.subject_mask, YkV=YkV)

    def mode2_bucket(self, b, proj, H: jax.Array, Wb: jax.Array) -> jax.Array:
        return self.mode2_compact(proj, H, Wb, b.col_mask, b.subject_mask)

    def mode3_bucket(self, b, proj, H: jax.Array,
                     V: Optional[jax.Array] = None, *, YkV=None) -> jax.Array:
        Vg = None if YkV is not None else b.gather_v(V)
        return self.mode3(proj, Vg, H, b.subject_mask, YkV=YkV)

    # -- per-bucket contractions --------------------------------------------
    def mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None) -> jax.Array:
        """Partial M1 [R, R] = sum_k (Y_k V) * W(k,:). With ``YkV`` cached
        (mode1_reuse), Vg may be None and the gather+matmul is skipped."""
        Yc, Vg, Wb, subject_mask, YkV = map(
            self.shard_subjects, (Yc, Vg, Wb, subject_mask, YkV))
        return self._mode1(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def mode2_compact(self, Yc, H, Wb, col_mask, subject_mask) -> jax.Array:
        """Compact A [Kb, C, R] = (Y_k^T H) * W(k,:); masked rows are 0."""
        Yc, Wb, col_mask, subject_mask = map(
            self.shard_subjects, (Yc, Wb, col_mask, subject_mask))
        return self.shard_subjects(
            self._mode2_compact(Yc, H, Wb, col_mask, subject_mask))

    def mode3(self, Yc, Vg, H, subject_mask, *, YkV=None) -> jax.Array:
        """Per-subject M3 rows [Kb, R] = coldot(H, Y_k V)."""
        Yc, Vg, subject_mask, YkV = map(
            self.shard_subjects, (Yc, Vg, subject_mask, YkV))
        return self.shard_subjects(self._mode3(Yc, Vg, H, subject_mask, YkV=YkV))

    @abc.abstractmethod
    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None) -> jax.Array: ...

    @abc.abstractmethod
    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask) -> jax.Array: ...

    @abc.abstractmethod
    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None) -> jax.Array: ...

    # -- whole-tensor helpers (the one callsite shape per mode) -------------
    def mttkrp_mode1(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     V: jax.Array, W: jax.Array) -> jax.Array:
        """M1 [R, R] over all buckets, with W global [K, R]."""
        return sum(
            self.mode1(Yc, b.gather_v(V), jnp.take(W, b.subject_ids, 0),
                       b.subject_mask)
            for b, Yc in zip(buckets, Ycs))

    def mttkrp_mode2(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     H: jax.Array, W: jax.Array, J: int) -> jax.Array:
        """M2 [J, R]: compact compute stage per bucket + shared scatter."""
        M2 = jnp.zeros((J, H.shape[0]), H.dtype)
        for b, Yc in zip(buckets, Ycs):
            A = self.mode2_compact(Yc, H, jnp.take(W, b.subject_ids, 0),
                                   b.col_mask, b.subject_mask)
            M2 = M2 + self.mode2_scatter(A, b.cols, J).astype(M2.dtype)
        return M2

    def mttkrp_mode3(self, buckets: Sequence, Ycs: Sequence[jax.Array],
                     V: jax.Array, H: jax.Array, K: int) -> jax.Array:
        """M3 [K, R]: per-subject rows scattered to global subject ids."""
        M3 = jnp.zeros((K, H.shape[0]), H.dtype)
        for b, Yc in zip(buckets, Ycs):
            rows = self.mode3(Yc, b.gather_v(V), H, b.subject_mask)
            M3 = M3.at[b.subject_ids].add(rows.astype(M3.dtype))
        return M3


class JnpBackend(MttkrpBackend):
    """The :mod:`repro.core.spartan` math — today's numerics, exactly."""

    name = "jnp"

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        return spartan.mode1_bucket(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return spartan.mode2_bucket_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        return spartan.mode3_bucket(Yc, Vg, H, subject_mask, YkV=YkV)


class PallasBackend(MttkrpBackend):
    """Routes through the Pallas kernels (:mod:`repro.kernels.ops`).

    Mosaic on TPU; interpret mode elsewhere. Kernel accumulators are f32, so
    outputs come back f32 regardless of input dtype; f64 inputs are demoted
    to f32 on the way in (use ``jnp`` for f64 algebra).
    """

    name = "pallas"

    @staticmethod
    def _k32(x: Optional[jax.Array]) -> Optional[jax.Array]:
        if x is not None and x.dtype == jnp.float64:
            return x.astype(jnp.float32)
        return x

    def ykv(self, Yc, Vg):
        from repro.kernels import ops
        return ops.ykv(self._k32(Yc), self._k32(Vg))

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        from repro.kernels import ops
        return ops.mttkrp_mode1(
            self._k32(Yc), self._k32(Vg), self._k32(Wb),
            subject_mask=self._k32(subject_mask), YkV=self._k32(YkV))

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        from repro.kernels import ops
        return ops.mttkrp_mode2_compact(
            self._k32(Yc), self._k32(H), self._k32(Wb),
            col_mask=self._k32(col_mask), subject_mask=self._k32(subject_mask))

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        from repro.kernels import ops
        return ops.mttkrp_mode3(
            self._k32(Yc), self._k32(Vg), self._k32(H),
            subject_mask=self._k32(subject_mask), YkV=self._k32(YkV))

    # SCOO buckets: the Pallas one-hot/scalar-prefetch segment-sum kernels
    # produce X_k V and the compact Yc (kernels/scoo.py); the per-stage CC
    # kernels then consume Yc exactly as for a CC bucket.
    def xkv_bucket(self, b, V, Vg=None):
        if isinstance(b, SparseBucket):
            from repro.kernels import scoo
            Vg = b.gather_v(V) if Vg is None else Vg
            return self.shard_subjects(scoo.xk_times_v(
                self._k32(b.vals), b.rows, b.lcols, self._k32(Vg), b.i_pad,
                nnz_counts=b.nnz_counts, use_pallas=True))
        return super().xkv_bucket(b, V, Vg)

    def project_bucket(self, b, Q):
        if isinstance(b, SparseBucket):
            from repro.kernels import scoo
            return self.shard_subjects(scoo.project(
                self._k32(b.vals), b.rows, b.lcols, self._k32(Q), b.c_pad,
                nnz_counts=b.nnz_counts, use_pallas=True))
        return super().project_bucket(b, Q)


class SparseBackend(MttkrpBackend):
    """The O(nnz) SCOO-native route (:mod:`repro.kernels.scoo`).

    On SCOO buckets the projected slices are never materialized:
    ``project_bucket`` returns Q itself and every downstream stage contracts
    the flat COO triplets directly (gather + segment-sum / outer-product
    accumulation). CC buckets — present in a mixed-format Bucketed from
    ``bucketize(format="auto")`` — delegate to the inner dense backend
    (``jnp`` by default), as do the array-level CC contraction methods.
    """

    name = "scoo"

    def __init__(self, inner: Optional[MttkrpBackend] = None):
        self._inner = inner if inner is not None else JnpBackend()

    # -- array-level CC contract: delegate wholesale ------------------------
    def ykv(self, Yc, Vg):
        return self._inner.ykv(Yc, Vg)

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        return self._inner._mode1(Yc, Vg, Wb, subject_mask, YkV=YkV)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return self._inner._mode2_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        return self._inner._mode3(Yc, Vg, H, subject_mask, YkV=YkV)

    # -- bucket-level stages: SCOO-native, Yc-free --------------------------
    def _ykv_native(self, b: SparseBucket, Q, V):
        from repro.kernels import scoo
        return scoo.ykv_scoo(b.vals, b.rows, b.lcols,
                             self.shard_subjects(Q), b.gather_v(V))

    def project_bucket(self, b, Q):
        if not isinstance(b, SparseBucket):
            return self._inner.project_bucket(b, Q)
        return self.shard_subjects(Q)   # carry Q; Yc is never built

    def ykv_bucket(self, b, proj, V):
        if not isinstance(b, SparseBucket):
            return self._inner.ykv_bucket(b, proj, V)
        return self._ykv_native(b, proj, V)

    def mode1_bucket(self, b, proj, Wb, V=None, *, YkV=None):
        if not isinstance(b, SparseBucket):
            return self._inner.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        if YkV is None:
            YkV = self._ykv_native(b, proj, V)
        # YkV in hand, the remaining Hadamard + subject reduction is the
        # shared R x R algebra (uniform shard constraints included)
        return self.mode1(None, None, Wb, b.subject_mask, YkV=YkV)

    def mode2_bucket(self, b, proj, H, Wb):
        if not isinstance(b, SparseBucket):
            return self._inner.mode2_bucket(b, proj, H, Wb)
        from repro.kernels import scoo
        Q, Wb, col_mask, smask = map(
            self.shard_subjects, (proj, Wb, b.col_mask, b.subject_mask))
        return self.shard_subjects(scoo.mode2_compact_scoo(
            b.vals, b.rows, b.lcols, Q, H, Wb, col_mask, smask,
            cperm=b.cperm, col_ends=b.col_ends))

    def mode3_bucket(self, b, proj, H, V=None, *, YkV=None):
        if not isinstance(b, SparseBucket):
            return self._inner.mode3_bucket(b, proj, H, V, YkV=YkV)
        if YkV is None:
            YkV = self._ykv_native(b, proj, V)
        return self.mode3(None, None, H, b.subject_mask, YkV=YkV)


class AutoBackend(MttkrpBackend):
    """Per-platform, per-bucket dispatch between jnp, pallas, and scoo.

    The decision is made at trace time from static bucket types and
    shapes/dtypes, so under jit each bucket compiles against exactly one
    implementation. SCOO buckets always take the O(nnz) native route
    (:class:`SparseBackend` — the format was chosen *because* the bucket is
    sparse, so the dense kernels are never the right answer for it); CC
    buckets the kernels handle poorly (odd R/C, f64, non-TPU platforms)
    fall back to jnp.
    """

    name = "auto"

    def __init__(self):
        self._jnp = JnpBackend()
        self._pallas = PallasBackend()
        self._sparse = SparseBackend(inner=self._jnp)

    # -- bucket-level: SCOO buckets -> the native sparse route --------------
    def xkv_bucket(self, b, V, Vg=None):
        if isinstance(b, SparseBucket):
            return self._sparse.xkv_bucket(b, V, Vg)
        return super().xkv_bucket(b, V, Vg)

    def project_bucket(self, b, Q):
        if isinstance(b, SparseBucket):
            return self._sparse.project_bucket(b, Q)
        return super().project_bucket(b, Q)

    def ykv_bucket(self, b, proj, V):
        if isinstance(b, SparseBucket):
            return self._sparse.ykv_bucket(b, proj, V)
        return super().ykv_bucket(b, proj, V)

    def mode1_bucket(self, b, proj, Wb, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode1_bucket(b, proj, Wb, V, YkV=YkV)
        return super().mode1_bucket(b, proj, Wb, V, YkV=YkV)

    def mode2_bucket(self, b, proj, H, Wb):
        if isinstance(b, SparseBucket):
            return self._sparse.mode2_bucket(b, proj, H, Wb)
        return super().mode2_bucket(b, proj, H, Wb)

    def mode3_bucket(self, b, proj, H, V=None, *, YkV=None):
        if isinstance(b, SparseBucket):
            return self._sparse.mode3_bucket(b, proj, H, V, YkV=YkV)
        return super().mode3_bucket(b, proj, H, V, YkV=YkV)

    @staticmethod
    def _platform_ok(probe: Optional[jax.Array]) -> bool:
        return (probe is not None and jax.default_backend() == "tpu"
                and probe.dtype != jnp.float64)

    @classmethod
    def _kernel_friendly(cls, probe: Optional[jax.Array]) -> bool:
        """Full C-contraction kernels: want R on the sublane quantum and the
        kept-column count C on the lane quantum (col_align=128 default)."""
        if not cls._platform_ok(probe):
            return False
        R, C = probe.shape[-2], probe.shape[-1]
        return R % 8 == 0 and C % 128 == 0

    @classmethod
    def _reuse_friendly(cls, YkV: Optional[jax.Array]) -> bool:
        """YkV-cached kernels only touch [Kb,R,R] tiles (VPU reductions), so
        only the sublane quantum matters — Mosaic lane-pads the small R."""
        if not cls._platform_ok(YkV):
            return False
        return YkV.shape[-1] % 8 == 0

    def _pick(self, probe, *, reuse: bool = False) -> MttkrpBackend:
        ok = self._reuse_friendly(probe) if reuse else self._kernel_friendly(probe)
        return self._pallas if ok else self._jnp

    def ykv(self, Yc, Vg):
        return self._pick(Yc).ykv(Yc, Vg)

    def _mode1(self, Yc, Vg, Wb, subject_mask, *, YkV=None):
        if YkV is not None:
            return self._pick(YkV, reuse=True)._mode1(
                Yc, Vg, Wb, subject_mask, YkV=YkV)
        return self._pick(Yc)._mode1(Yc, Vg, Wb, subject_mask, YkV=None)

    def _mode2_compact(self, Yc, H, Wb, col_mask, subject_mask):
        return self._pick(Yc)._mode2_compact(Yc, H, Wb, col_mask, subject_mask)

    def _mode3(self, Yc, Vg, H, subject_mask, *, YkV=None):
        if YkV is not None:
            return self._pick(YkV, reuse=True)._mode3(
                Yc, Vg, H, subject_mask, YkV=YkV)
        return self._pick(Yc)._mode3(Yc, Vg, H, subject_mask, YkV=None)


BACKENDS = {"jnp": JnpBackend(), "pallas": PallasBackend(),
            "scoo": SparseBackend(), "auto": AutoBackend()}


def get_backend(name) -> MttkrpBackend:
    """Resolve a backend by name ("jnp" | "pallas" | "scoo" | "auto") or pass
    an :class:`MttkrpBackend` instance through unchanged."""
    if isinstance(name, MttkrpBackend):
        return name
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown MTTKRP backend {name!r}; choose from {sorted(BACKENDS)}"
        ) from None
