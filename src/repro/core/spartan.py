"""SPARTan MTTKRP — the paper's core contribution, on the CC bucketed format.

All three modes operate directly on the frontal slices Y_k (never forming the
R x J x K intermediate tensor), are batched over subjects inside a bucket, and
exploit column sparsity via the CC gather. Partial sums over subjects are
plain adds — under pjit with subjects sharded over the mesh (the "subjects"
rule in repro.dist.sharding) they lower to all-reduces, which is the paper's
"sum partial results in parallel".

This module is pure math: the functions here are the ``jnp`` implementation
behind :class:`repro.core.backend.JnpBackend`. Backend selection, the
whole-tensor per-mode helpers, and the uniform subject-axis sharding
constraints all live in :mod:`repro.core.backend` — the one layer the ALS
driver talks to. See docs/ARCHITECTURE.md for the end-to-end data flow.

Shapes per bucket (Kb subjects, I rows padded, C kept-cols padded, rank R):
  Yc  [Kb, R, C]   compressed slices  Y_k = Q_k^T X_k
  Vg  [Kb, C, R]   gathered V rows for kept columns
  Wb  [Kb, R]      W rows for this bucket's subjects
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import accum_dtype

__all__ = [
    "mode1_bucket",
    "mode2_bucket_compact",
    "mode2_scatter",
    "mode3_bucket",
]


def _f(x):
    """Promote to the shared accumulation dtype (``kernels.common.accum_dtype``):
    bf16/f16 slice values feed subject-axis reductions, which lose mass in half
    precision, so they widen to f32. f32/f64 pass through unchanged (the f64
    algebra tests must stay exact)."""
    return x.astype(accum_dtype(x))


# ---------------------------------------------------------------------------
# Mode 1:  M1 = sum_k (Y_k V) * W(k,:)  (row-wise Hadamard)  -> [R, R]
# ---------------------------------------------------------------------------

def mode1_bucket(
    Yc: jax.Array,
    Vg: jax.Array,
    Wb: jax.Array,
    subject_mask: jax.Array,
    *,
    YkV: Optional[jax.Array] = None,
) -> jax.Array:
    """Partial M1 for one bucket. If ``YkV`` ([Kb,R,R], = Y_k V) is provided
    (mode1_reuse optimization: Y_k V = Q_k^T (X_k V) cached from the Procrustes
    step), the gather+matmul is skipped entirely."""
    if YkV is None:
        YkV = jnp.einsum("krc,kcl->krl", _f(Yc), _f(Vg))  # [Kb, R, R]
    scaled = _f(YkV) * _f(Wb)[:, None, :]         # row-wise Hadamard with W(k,:)
    return jnp.einsum("krl,k->rl", scaled, subject_mask)


# ---------------------------------------------------------------------------
# Mode 2:  temp(j,:) = (Y_k(:,j)^T H) * W(k,:) for nonzero cols j; scatter-add
# ---------------------------------------------------------------------------

def mode2_bucket_compact(
    Yc: jax.Array,
    H: jax.Array,
    Wb: jax.Array,
    col_mask: jax.Array,
    subject_mask: jax.Array,
) -> jax.Array:
    """Compact per-column results A[Kb, C, R]; rows for padded columns are 0.

    This is the compute stage of mode-2 (the paper's Fig. 3): one small matmul
    per subject over its kept columns only, then Hadamard with W(k,:).
    The scatter to M2 in R^{J x R} is a separate, memory-bound stage.
    """
    A = jnp.einsum("krc,rl->kcl", _f(Yc), H)                   # (Y_k(:,j)^T H)
    A = A * _f(Wb)[:, None, :]                                 # * W(k,:)
    return A * (col_mask * subject_mask[:, None])[..., None]


def mode2_scatter(A: jax.Array, cols: jax.Array, J: int) -> jax.Array:
    """Scatter-add compact results into M2 [J, R]. Padded entries are zero so
    scattering them to column id 0 (or any segment) is harmless.

    When ``cols`` is a trace-time CONSTANT — the host/scan/while engines jit
    ``als_step`` with the bucket closed over, so the kept-column metadata is a
    concrete array during tracing — the column order is presorted once with
    numpy at trace time and the XLA scatter-add (scalar-serialized on CPU,
    ~2.5x the cost of this path at benchmark scale) is replaced by a
    permutation gather + cumsum-diff segment sum over the sorted rows.
    Under shard_map (mesh engine) or AOT lowering with the data as a runtime
    argument ``cols`` is a tracer and the plain scatter-add runs instead —
    a [Kb*C]-flat global sort cannot be sharded over subjects.
    """
    Kb, C, R = A.shape
    flat_cols = cols.reshape(-1)                               # [Kb*C]
    flat_A = A.reshape(-1, R)
    if not isinstance(flat_cols, jax.core.Tracer):
        cnp = np.asarray(flat_cols)
        perm = np.argsort(cnp, kind="stable")
        ends = np.searchsorted(cnp[perm], np.arange(1, J + 1))
        # accumulate in f64 when x64 is on (canonicalized back to f32
        # otherwise) — the running cumsum spans every kept column, so give
        # the partial sums the wider accumulator when one is available
        acc = jnp.result_type(A.dtype, jnp.float64)
        g = flat_A[jnp.asarray(perm)].astype(acc)
        cs = jnp.concatenate([jnp.zeros((1, R), acc), jnp.cumsum(g, 0)], 0)
        seg = cs[jnp.asarray(ends)]                            # [J, R]
        return jnp.diff(seg, axis=0,
                        prepend=jnp.zeros((1, R), acc)).astype(A.dtype)
    return jnp.zeros((J, R), A.dtype).at[flat_cols].add(flat_A)


# ---------------------------------------------------------------------------
# Mode 3:  M3(k,:) = coldot(H, Y_k V)   -> [K, R] rows per subject
# ---------------------------------------------------------------------------

def mode3_bucket(
    Yc: jax.Array,
    Vg: jax.Array,
    H: jax.Array,
    subject_mask: jax.Array,
    *,
    YkV: Optional[jax.Array] = None,
) -> jax.Array:
    """Per-subject rows of M3 for one bucket: [Kb, R]."""
    if YkV is None:
        YkV = jnp.einsum("krc,kcl->krl", _f(Yc), _f(Vg))
    rows = jnp.einsum("rl,krl->kl", H, _f(YkV))   # column-wise inner products
    return rows * subject_mask[:, None]
