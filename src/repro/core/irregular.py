"""Device-side irregular-tensor formats.

Two formats, both static-shape (XLA) and bucketed (see repro.sparse.bucketing):

* **CC (compressed columns)** — each subject slice X_k (I_k x J) is stored
  *dense over its nonzero columns*: ``vals[k] in R^{I_pad x C_pad}`` plus the
  global column ids ``cols[k] in {0..J-1}^{C_pad}``. This is the functional
  format for all SPARTan math: every identity in the paper becomes a gather
  of V-rows plus a small dense matmul (MXU-shaped).

* **BCC (block-compressed columns)** — same idea with column indices quantized
  to 128-wide blocks of J; this is the Pallas-kernel format (scalar-prefetch
  block gathers). Conversion CC -> BCC is provided.

A :class:`Bucketed` value is a pytree (dict of buckets) usable under jit/pjit;
subjects shard along the leading Kb axis of every per-bucket array — the
"subjects" rule in :mod:`repro.dist.sharding`. See docs/ARCHITECTURE.md
(stage 2) for where these formats sit in the end-to-end data flow.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.sparse.coo import IrregularCOO
from repro.sparse.bucketing import BucketPlan, plan_buckets

__all__ = ["Bucket", "Bucketed", "bucketize", "LANE"]

LANE = 128  # TPU lane width; BCC column-block quantum


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Bucket:
    """One static-shape bucket of subjects in CC format.

    vals:        f[Kb, I_pad, C_pad]  dense values over kept columns
    cols:        i32[Kb, C_pad]       global column id per kept column (pad: 0)
    col_mask:    f[Kb, C_pad]         1.0 for real kept columns, 0.0 for padding
    subject_ids: i32[Kb]              global subject index (row into W)
    subject_mask:f[Kb]                1.0 real subject, 0.0 padding subject
    row_counts:  i32[Kb]              true I_k (informational; padded rows are 0)
    """

    vals: jax.Array
    cols: jax.Array
    col_mask: jax.Array
    subject_ids: jax.Array
    subject_mask: jax.Array
    row_counts: jax.Array

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (
            self.vals,
            self.cols,
            self.col_mask,
            self.subject_ids,
            self.subject_mask,
            self.row_counts,
        )
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- shape helpers -----------------------------------------------------
    @property
    def kb(self) -> int:
        return self.vals.shape[0]

    @property
    def i_pad(self) -> int:
        return self.vals.shape[1]

    @property
    def c_pad(self) -> int:
        return self.vals.shape[2]

    # -- core contractions (all batched over Kb) ----------------------------
    def gather_v(self, V: jax.Array) -> jax.Array:
        """V-rows for this bucket's kept columns: [Kb, C_pad, R] (pad rows 0)."""
        Vg = jnp.take(V, self.cols, axis=0)  # [Kb, C_pad, R]
        return Vg * self.col_mask[..., None]

    def xk_times_v(self, V: jax.Array, Vg: Optional[jax.Array] = None) -> jax.Array:
        """X_k V for every subject: [Kb, I_pad, R]. The paper's column-sparsity
        exploitation: only V rows of kept columns participate."""
        if Vg is None:
            Vg = self.gather_v(V)
        return jnp.einsum("kic,kcr->kir", self.vals, Vg, preferred_element_type=self.vals.dtype)

    def xk_times_v_bcc(self, bcc: "BlockBucket", V: jax.Array) -> jax.Array:
        """X_k V through the Pallas BCC scalar-prefetch kernel (TPU path;
        interpret=True off-TPU). V is zero-padded to a LANE multiple."""
        from repro.kernels import ops

        J, R = V.shape
        J_pad = ((J + LANE - 1) // LANE) * LANE
        V_pad = jnp.zeros((J_pad, R), V.dtype).at[:J].set(V) if J_pad != J else V
        return ops.gather_matmul(bcc.vals, bcc.blk_ids, V_pad).astype(self.vals.dtype)

    def project(self, Q: jax.Array) -> jax.Array:
        """Y_k = Q_k^T X_k in CC format: [Kb, R, C_pad]; shares self.cols.

        This is the paper's key structural observation: Y_k inherits exactly
        the column-sparsity pattern of X_k.
        """
        return jnp.einsum("kir,kic->krc", Q, self.vals, preferred_element_type=self.vals.dtype)

    def scatter_cols_to_dense(self, compact: jax.Array, J: int) -> jax.Array:
        """Expand a CC matrix [Kb, *, C_pad] back to dense [Kb, *, J] (tests)."""
        Kb, mid, Cp = compact.shape
        out = jnp.zeros((Kb, mid, J), compact.dtype)
        k_idx = jnp.arange(Kb)[:, None, None]
        m_idx = jnp.arange(mid)[None, :, None]
        c_idx = self.cols[:, None, :]
        return out.at[k_idx, m_idx, c_idx].add(compact * self.col_mask[:, None, :])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Bucketed:
    """A bucketed irregular tensor: static-shape buckets + global metadata.

    Registered as a pytree (buckets are children; K/J/norm_sq are static aux)
    so the whole dataset is a jit/pjit argument — the dry-run lowers als_step
    against ShapeDtypeStruct buckets with subjects sharded over (pod, data).
    """

    buckets: List[Bucket]
    n_subjects: int          # K (true count, before subject padding)
    n_cols: int              # J
    norm_sq: float           # ||X||_F^2 over all subjects (for fit computation)

    def tree_flatten(self):
        return (self.buckets,), (self.n_subjects, self.n_cols, self.norm_sq)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(buckets=children[0], n_subjects=aux[0], n_cols=aux[1], norm_sq=aux[2])

    def tree_buckets(self) -> List[Bucket]:
        return self.buckets


def _pad_to(n: int, align: int) -> int:
    return max(align, ((n + align - 1) // align) * align)


def bucketize(
    data: IrregularCOO,
    *,
    max_buckets: int = 4,
    row_align: int = 8,
    col_align: int = 128,
    subject_align: int = 1,
    dtype=jnp.float32,
    plan: Optional[BucketPlan] = None,
) -> Bucketed:
    """Host-side conversion IrregularCOO -> Bucketed CC format.

    ``subject_align`` pads each bucket's subject count to a multiple (use the
    data-parallel shard count so the leading axis divides evenly).
    """
    rc = data.row_counts()
    cc = data.col_counts()
    if plan is None:
        plan = plan_buckets(rc, cc, max_buckets=max_buckets, row_align=row_align, col_align=col_align)
    buckets: List[Bucket] = []
    for (i_pad, c_pad), members in zip(plan.shapes, plan.members):
        kb = _pad_to(len(members), subject_align)
        vals = np.zeros((kb, i_pad, c_pad), dtype=np.float32 if dtype == jnp.float32 else np.float64)
        cols = np.zeros((kb, c_pad), dtype=np.int32)
        cmask = np.zeros((kb, c_pad), dtype=vals.dtype)
        sids = np.zeros((kb,), dtype=np.int32)
        smask = np.zeros((kb,), dtype=vals.dtype)
        rows_n = np.zeros((kb,), dtype=np.int32)
        for slot, k in enumerate(members):
            s = data.subjects[k]
            kept = s.nonzero_cols()
            remap = {int(c): i for i, c in enumerate(kept)}
            local_c = np.asarray([remap[int(c)] for c in s.cols], dtype=np.int32)
            vals[slot, s.rows, local_c] = s.vals
            cols[slot, : kept.size] = kept
            cmask[slot, : kept.size] = 1.0
            sids[slot] = k
            smask[slot] = 1.0
            rows_n[slot] = s.n_rows
        buckets.append(
            Bucket(
                vals=jnp.asarray(vals, dtype=dtype),
                cols=jnp.asarray(cols),
                col_mask=jnp.asarray(cmask, dtype=dtype),
                subject_ids=jnp.asarray(sids),
                subject_mask=jnp.asarray(smask, dtype=dtype),
                row_counts=jnp.asarray(rows_n),
            )
        )
    return Bucketed(
        buckets=buckets,
        n_subjects=data.n_subjects,
        n_cols=data.n_cols,
        norm_sq=data.frobenius_sq(),
    )


# ---------------------------------------------------------------------------
# BCC: block-compressed columns (Pallas kernel layout)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockBucket:
    """BCC layout: columns quantized to LANE-wide blocks of J.

    vals:     f[Kb, I_pad, NB, LANE]  dense values per kept column-block
    blk_ids:  i32[Kb, NB]             global block index (j // LANE) (pad: 0)
    blk_mask: f[Kb, NB]               1.0 for real blocks
    """

    vals: jax.Array
    blk_ids: jax.Array
    blk_mask: jax.Array
    subject_ids: jax.Array
    subject_mask: jax.Array

    def tree_flatten(self):
        return (self.vals, self.blk_ids, self.blk_mask, self.subject_ids, self.subject_mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def kb(self):
        return self.vals.shape[0]

    @property
    def i_pad(self):
        return self.vals.shape[1]

    @property
    def n_blocks(self):
        return self.vals.shape[2]


def to_block_bucket(b: Bucket, J: int, *, max_blocks: Optional[int] = None) -> BlockBucket:
    """Host-side CC -> BCC conversion (column ids quantized to LANE blocks)."""
    vals = np.asarray(b.vals)
    cols = np.asarray(b.cols)
    cmask = np.asarray(b.col_mask) > 0
    kb, i_pad, _ = vals.shape
    per_subject_blocks = []
    for k in range(kb):
        kept = cols[k][cmask[k]]
        per_subject_blocks.append(np.unique(kept // LANE) if kept.size else np.zeros((0,), np.int64))
    nb = max((blk.size for blk in per_subject_blocks), default=1)
    nb = max(nb, 1)
    if max_blocks is not None:
        nb = min(nb, max_blocks)
    out_vals = np.zeros((kb, i_pad, nb, LANE), dtype=vals.dtype)
    blk_ids = np.zeros((kb, nb), dtype=np.int32)
    blk_mask = np.zeros((kb, nb), dtype=vals.dtype)
    for k in range(kb):
        blocks = per_subject_blocks[k][:nb]
        pos = {int(bid): i for i, bid in enumerate(blocks)}
        blk_ids[k, : blocks.size] = blocks
        blk_mask[k, : blocks.size] = 1.0
        kept_idx = np.nonzero(cmask[k])[0]
        for ci in kept_idx:
            gcol = int(cols[k, ci])
            bslot = pos.get(gcol // LANE)
            if bslot is None:
                continue  # truncated by max_blocks
            out_vals[k, :, bslot, gcol % LANE] = vals[k, :, ci]
    return BlockBucket(
        vals=jnp.asarray(out_vals),
        blk_ids=jnp.asarray(blk_ids),
        blk_mask=jnp.asarray(blk_mask),
        subject_ids=b.subject_ids,
        subject_mask=b.subject_mask,
    )
