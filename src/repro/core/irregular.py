"""Device-side irregular-tensor formats.

Three formats, all static-shape (XLA) and bucketed (see repro.sparse.bucketing):

* **CC (compressed columns)** — each subject slice X_k (I_k x J) is stored
  *dense over its nonzero columns*: ``vals[k] in R^{I_pad x C_pad}`` plus the
  global column ids ``cols[k] in {0..J-1}^{C_pad}``. This is the functional
  format for all SPARTan math: every identity in the paper becomes a gather
  of V-rows plus a small dense matmul (MXU-shaped). Cost per iteration:
  O(Kb * I_pad * C_pad * R) regardless of the true nonzero count.

* **SCOO (sorted flat COO)** — each subject's nonzeros as flat triplets
  ``vals[k] in R^{N_pad}`` + local ``rows``/``lcols`` indices, sorted
  row-major and padded to the bucket-wide N_pad (subject-aligned padding:
  every subject owns exactly one N_pad segment, so the flat nnz axis is just
  the [Kb, N_pad] leading-axis layout and ``nnz_offsets`` are uniform). The
  kept-column ids/mask are shared with CC, so the projected slices Y_k land
  in the identical compact [R, C_pad] layout. Every contraction is a
  gather + segment-sum in O(nnz * R) — see :mod:`repro.kernels.scoo`. This
  is the format for genuinely sparse buckets (EHR-like ~1% intra-slice
  density), where CC's densified rectangle burns ~100x the FLOPs and HBM.

* **BCC (block-compressed columns)** — CC with column indices quantized
  to 128-wide blocks of J; this is the Pallas-kernel format (scalar-prefetch
  block gathers). Conversion CC -> BCC is provided.

``bucketize(format=...)`` picks per bucket: ``"cc"`` / ``"scoo"`` force one
format, ``"auto"`` routes each bucket by its measured density (nonzeros over
the densified CC cell count) through :func:`repro.sparse.bucketing.
route_formats`. A :class:`Bucketed` value may therefore mix Bucket and
SparseBucket children; both are pytrees usable under jit/pjit, and subjects
shard along the leading Kb axis of every per-bucket array — the "subjects"
rule in :mod:`repro.dist.sharding`. See docs/ARCHITECTURE.md (stage 2) for
where these formats sit in the end-to-end data flow.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.sparse.coo import IrregularCOO
from repro.sparse.bucketing import BucketPlan, plan_buckets, route_formats
from repro.sparse.bucketing import SCOO_DENSITY_THRESHOLD

__all__ = ["Bucket", "SparseBucket", "Bucketed", "bucketize", "bucket_format",
           "cc_bucket_like", "FORMATS", "LANE"]

LANE = 128  # TPU lane width; BCC column-block quantum

FORMATS = ("cc", "scoo", "auto")  # bucketize(format=...) choices


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Bucket:
    """One static-shape bucket of subjects in CC format.

    vals:        f[Kb, I_pad, C_pad]  dense values over kept columns
    cols:        i32[Kb, C_pad]       global column id per kept column (pad: 0)
    col_mask:    f[Kb, C_pad]         1.0 for real kept columns, 0.0 for padding
    subject_ids: i32[Kb]              global subject index (row into W)
    subject_mask:f[Kb]                1.0 real subject, 0.0 padding subject
    row_counts:  i32[Kb]              true I_k (informational; padded rows are 0)
    """

    vals: jax.Array
    cols: jax.Array
    col_mask: jax.Array
    subject_ids: jax.Array
    subject_mask: jax.Array
    row_counts: jax.Array

    format = "cc"  # class tag, not a field (see bucket_format)

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (
            self.vals,
            self.cols,
            self.col_mask,
            self.subject_ids,
            self.subject_mask,
            self.row_counts,
        )
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- shape helpers -----------------------------------------------------
    @property
    def kb(self) -> int:
        return self.vals.shape[0]

    @property
    def i_pad(self) -> int:
        return self.vals.shape[1]

    @property
    def c_pad(self) -> int:
        return self.vals.shape[2]

    # -- core contractions (all batched over Kb) ----------------------------
    def gather_v(self, V: jax.Array) -> jax.Array:
        """V-rows for this bucket's kept columns: [Kb, C_pad, R] (pad rows 0)."""
        Vg = jnp.take(V, self.cols, axis=0)  # [Kb, C_pad, R]
        return Vg * self.col_mask[..., None]

    def xk_times_v(self, V: jax.Array, Vg: Optional[jax.Array] = None) -> jax.Array:
        """X_k V for every subject: [Kb, I_pad, R]. The paper's column-sparsity
        exploitation: only V rows of kept columns participate."""
        if Vg is None:
            Vg = self.gather_v(V)
        return jnp.einsum("kic,kcr->kir", self.vals, Vg, preferred_element_type=self.vals.dtype)

    def xk_times_v_bcc(self, bcc: "BlockBucket", V: jax.Array) -> jax.Array:
        """X_k V through the Pallas BCC scalar-prefetch kernel (TPU path;
        interpret=True off-TPU). V is zero-padded to a LANE multiple."""
        from repro.kernels import ops

        J, R = V.shape
        J_pad = ((J + LANE - 1) // LANE) * LANE
        V_pad = jnp.zeros((J_pad, R), V.dtype).at[:J].set(V) if J_pad != J else V
        return ops.gather_matmul(bcc.vals, bcc.blk_ids, V_pad).astype(self.vals.dtype)

    def project(self, Q: jax.Array) -> jax.Array:
        """Y_k = Q_k^T X_k in CC format: [Kb, R, C_pad]; shares self.cols.

        This is the paper's key structural observation: Y_k inherits exactly
        the column-sparsity pattern of X_k.
        """
        return jnp.einsum("kir,kic->krc", Q, self.vals, preferred_element_type=self.vals.dtype)

    def sq_norms(self) -> jax.Array:
        """Per-subject ||X_k||_F^2 [Kb] (padding slots contribute 0) — the
        streaming update path's residual bookkeeping needs the norm per
        subject, not just the dataset-wide ``Bucketed.norm_sq``."""
        return jnp.sum(self.vals * self.vals, axis=(1, 2))

    def scatter_cols_to_dense(self, compact: jax.Array, J: int) -> jax.Array:
        """Expand a CC matrix [Kb, *, C_pad] back to dense [Kb, *, J] (tests)."""
        Kb, mid, Cp = compact.shape
        out = jnp.zeros((Kb, mid, J), compact.dtype)
        k_idx = jnp.arange(Kb)[:, None, None]
        m_idx = jnp.arange(mid)[None, :, None]
        c_idx = self.cols[:, None, :]
        return out.at[k_idx, m_idx, c_idx].add(compact * self.col_mask[:, None, :])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseBucket:
    """One static-shape bucket of subjects in SCOO (sorted flat COO) format.

    vals:        f[Kb, N_pad]        nonzero values, row-major sorted per
                                     subject (pad entries 0 — they vanish in
                                     every segment-sum)
    rows:        i32[Kb, N_pad]      local row index in the I_pad row space
                                     (pad: 0 — harmless, its value is 0)
    lcols:       i32[Kb, N_pad]      local kept-column slot in [0, C_pad)
    row_ends:    i32[Kb, I_pad]      CSR-style pointers: one past row i's
                                     last triplet (pads excluded)
    cperm:       i32[Kb, N_pad]      permutation into column-sorted order
                                     (pads stay at the tail)
    col_ends:    i32[Kb, C_pad]      CSC-style pointers into the cperm view
    cols:        i32[Kb, C_pad]      global column id per kept column (pad: 0)
    col_mask:    f[Kb, C_pad]        1.0 for real kept columns
    subject_ids: i32[Kb]             global subject index (row into W)
    subject_mask:f[Kb]               1.0 real subject, 0.0 padding subject
    row_counts:  i32[Kb]             true I_k
    nnz_counts:  i32[Kb]             true nnz_k (<= N_pad; pad subjects 0)
    n_rows_pad:  int (static)        I_pad — the padded row space Q/XkV use

    Subject-aligned padding makes the per-subject flat offsets uniform
    (``nnz_offsets`` is just ``arange(Kb) * N_pad``), so the triplet arrays
    are [Kb, N_pad] with subjects on the leading axis — the same sharding
    story as CC. The sorted order plus the precomputed row/column segment
    boundaries make every segment-sum a cumsum + gather + diff — no
    scatter-add on the hot path (repro.kernels.scoo). The kept-column
    metadata (cols/col_mask) is shared with CC, so ``project`` lands in the
    identical compact Yc layout and every downstream MTTKRP stage is
    format-agnostic.
    """

    vals: jax.Array
    rows: jax.Array
    lcols: jax.Array
    row_ends: jax.Array
    cperm: jax.Array
    col_ends: jax.Array
    cols: jax.Array
    col_mask: jax.Array
    subject_ids: jax.Array
    subject_mask: jax.Array
    row_counts: jax.Array
    nnz_counts: jax.Array
    n_rows_pad: int  # static aux (not derivable from the triplet shapes)

    format = "scoo"

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (
            self.vals, self.rows, self.lcols, self.row_ends, self.cperm,
            self.col_ends, self.cols, self.col_mask,
            self.subject_ids, self.subject_mask, self.row_counts,
            self.nnz_counts,
        )
        return children, (self.n_rows_pad,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_rows_pad=aux[0])

    # -- shape helpers -----------------------------------------------------
    @property
    def kb(self) -> int:
        return self.vals.shape[0]

    @property
    def i_pad(self) -> int:
        return self.n_rows_pad

    @property
    def c_pad(self) -> int:
        return self.cols.shape[1]

    @property
    def n_pad(self) -> int:
        return self.vals.shape[1]

    @property
    def nnz_offsets(self) -> jax.Array:
        """Per-subject start offset into the flattened nnz axis — uniform
        because the padding is subject-aligned."""
        return jnp.arange(self.kb, dtype=jnp.int32) * self.n_pad

    # -- core contractions (all batched over Kb, all O(nnz * R)) ------------
    def gather_v(self, V: jax.Array) -> jax.Array:
        """V-rows for this bucket's kept columns: [Kb, C_pad, R] (pad rows 0).
        Identical to CC — the kept-column metadata is shared."""
        Vg = jnp.take(V, self.cols, axis=0)
        return Vg * self.col_mask[..., None]

    def xk_times_v(self, V: jax.Array, Vg: Optional[jax.Array] = None) -> jax.Array:
        """X_k V for every subject: [Kb, I_pad, R] — gather-from-V +
        sorted segment-sum over rows (repro.kernels.scoo)."""
        from repro.kernels import scoo

        if Vg is None:
            Vg = self.gather_v(V)
        return scoo.xk_times_v(self.vals, self.rows, self.lcols, Vg,
                               self.i_pad, row_ends=self.row_ends)

    def project(self, Q: jax.Array) -> jax.Array:
        """Y_k = Q_k^T X_k: [Kb, R, C_pad] — gather-from-Q + sorted
        segment-sum over kept columns; shares self.cols, so the output is
        the CC Yc layout."""
        from repro.kernels import scoo

        return scoo.project(self.vals, self.rows, self.lcols, Q, self.c_pad,
                            cperm=self.cperm, col_ends=self.col_ends)

    def sq_norms(self) -> jax.Array:
        """Per-subject ||X_k||_F^2 [Kb] — pad triplets are 0-valued, so the
        flat sum needs no masking (same contract as :meth:`Bucket.sq_norms`)."""
        return jnp.sum(self.vals * self.vals, axis=1)

    def dense_vals(self) -> jax.Array:
        """Materialize the CC vals rectangle [Kb, I_pad, C_pad] (tests)."""
        Kb, _ = self.vals.shape
        out = jnp.zeros((Kb, self.i_pad, self.c_pad), self.vals.dtype)
        k_idx = jnp.arange(Kb)[:, None]
        return out.at[k_idx, self.rows, self.lcols].add(self.vals)

    def scatter_cols_to_dense(self, compact: jax.Array, J: int) -> jax.Array:
        """Expand a compact matrix [Kb, *, C_pad] back to dense [Kb, *, J]
        (tests) — same column metadata as CC."""
        Kb, mid, Cp = compact.shape
        out = jnp.zeros((Kb, mid, J), compact.dtype)
        k_idx = jnp.arange(Kb)[:, None, None]
        m_idx = jnp.arange(mid)[None, :, None]
        c_idx = self.cols[:, None, :]
        return out.at[k_idx, m_idx, c_idx].add(compact * self.col_mask[:, None, :])


def bucket_format(b) -> str:
    """Device-format tag of a bucket: "cc" | "scoo" (BCC buckets are a
    kernel-side conversion, never stored in a Bucketed)."""
    return getattr(b, "format", "cc")


def cc_bucket_like(b, vals: jax.Array,
                   row_counts: Optional[jax.Array] = None) -> Bucket:
    """A CC :class:`Bucket` holding ``vals`` [Kb, I', C_pad] under ``b``'s
    column/subject metadata (``b`` may be CC or SCOO — the metadata contract
    is shared). The row space I' may differ from ``b.i_pad``: this is how the
    compression stage (:mod:`repro.core.compress`) wraps the small cores
    ``G_k = P_k^T X_k`` as an ordinary bucket the engines iterate on.
    """
    if vals.shape[0] != b.kb or vals.shape[2] != b.c_pad:
        raise ValueError(
            f"vals shape {vals.shape} does not match bucket metadata "
            f"(Kb={b.kb}, C_pad={b.c_pad})")
    return Bucket(
        vals=vals, cols=b.cols, col_mask=b.col_mask,
        subject_ids=b.subject_ids, subject_mask=b.subject_mask,
        row_counts=b.row_counts if row_counts is None else row_counts)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Bucketed:
    """A bucketed irregular tensor: static-shape buckets + global metadata.

    Registered as a pytree (buckets are children; K/J/norm_sq are static aux)
    so the whole dataset is a jit/pjit argument — the dry-run lowers als_step
    against ShapeDtypeStruct buckets with subjects sharded over (pod, data).
    """

    buckets: List[Bucket]
    n_subjects: int          # K (true count, before subject padding)
    n_cols: int              # J
    norm_sq: float           # ||X||_F^2 over all subjects (for fit computation)

    def tree_flatten(self):
        return (self.buckets,), (self.n_subjects, self.n_cols, self.norm_sq)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(buckets=children[0], n_subjects=aux[0], n_cols=aux[1], norm_sq=aux[2])

    def tree_buckets(self) -> List[Bucket]:
        return self.buckets


def _pad_to(n: int, align: int) -> int:
    return max(align, ((n + align - 1) // align) * align)


def _staging_dtype(dtype) -> np.dtype:
    """Host staging-buffer dtype for device values of ``dtype``: f64 only
    when f64 is actually requested; every other float (f32, bf16, f16, ...)
    stages in f32 and is cast ONCE at device upload. (The old check compared
    against f32 only, silently staging bf16/f16 requests in f64.)"""
    if jnp.dtype(dtype) == jnp.float64:
        return np.dtype(np.float64)
    return np.dtype(np.float32)


def bucketize(
    data: IrregularCOO,
    *,
    max_buckets: int = 4,
    row_align: int = 8,
    col_align: int = 128,
    subject_align: int = 1,
    nnz_align: int = 8,
    dtype=jnp.float32,
    plan: Optional[BucketPlan] = None,
    format: str = "cc",
    formats: Optional[Sequence[str]] = None,
    density_threshold: float = SCOO_DENSITY_THRESHOLD,
) -> Bucketed:
    """Host-side conversion IrregularCOO -> Bucketed device format.

    ``format`` picks the per-bucket device layout: ``"cc"`` (dense over kept
    columns — the historical default), ``"scoo"`` (flat sorted COO triplets,
    O(nnz) algebra; the planner pads *nnz*, not area, and quantile-buckets by
    nnz), or ``"auto"`` (each bucket routed by its measured density through
    :func:`repro.sparse.bucketing.route_formats`; below ``density_threshold``
    -> SCOO). ``formats`` overrides the routing with an explicit per-bucket
    list (must match ``plan``'s bucket count).

    ``subject_align`` pads each bucket's subject count to a multiple (use the
    data-parallel shard count so the leading axis divides evenly);
    ``nnz_align`` rounds SCOO buckets' per-subject N_pad.
    """
    if format not in FORMATS:
        raise ValueError(f"unknown format {format!r}; choose from {FORMATS}")
    rc = data.row_counts()
    cc = data.col_counts()
    nnzc = data.nnz_counts()
    if plan is None:
        plan = plan_buckets(
            rc, cc, max_buckets=max_buckets, row_align=row_align,
            col_align=col_align, nnz_counts=nnzc, nnz_align=nnz_align,
            sort_by="nnz" if format == "scoo" else "area")
    if formats is None:
        formats = route_formats(plan, nnzc, format=format,
                                density_threshold=density_threshold)
    if len(formats) != plan.n_buckets:
        raise ValueError(
            f"formats has {len(formats)} entries for {plan.n_buckets} buckets")
    stage = _staging_dtype(dtype)
    buckets: List = []
    for bi, ((i_pad, c_pad), members) in enumerate(zip(plan.shapes, plan.members)):
        kb = _pad_to(len(members), subject_align)
        fmt = formats[bi]
        cols = np.zeros((kb, c_pad), dtype=np.int32)
        cmask = np.zeros((kb, c_pad), dtype=stage)
        sids = np.zeros((kb,), dtype=np.int32)
        smask = np.zeros((kb,), dtype=stage)
        rows_n = np.zeros((kb,), dtype=np.int32)
        if fmt == "cc":
            vals = np.zeros((kb, i_pad, c_pad), dtype=stage)
        elif fmt == "scoo":
            if plan.nnz_pads is not None:
                n_pad = plan.nnz_pads[bi]
            else:
                n_pad = _pad_to(int(max((nnzc[k] for k in members),
                                        default=1)), nnz_align)
            vals = np.zeros((kb, n_pad), dtype=stage)
            trip_rows = np.zeros((kb, n_pad), dtype=np.int32)
            trip_lcols = np.zeros((kb, n_pad), dtype=np.int32)
            row_ends = np.zeros((kb, i_pad), dtype=np.int32)
            # pads keep identity slots at the tail of the col-sorted view;
            # their value is 0 and every col_end is <= nnz, so they never
            # land in a segment
            cperm = np.tile(np.arange(n_pad, dtype=np.int32), (kb, 1))
            col_ends = np.zeros((kb, c_pad), dtype=np.int32)
            nnz_n = np.zeros((kb,), dtype=np.int32)
        else:
            raise ValueError(f"unknown bucket format {fmt!r}")
        for slot, k in enumerate(members):
            s = data.subjects[k]
            kept = s.nonzero_cols()
            remap = {int(c): i for i, c in enumerate(kept)}
            local_c = np.asarray([remap[int(c)] for c in s.cols], dtype=np.int32)
            if fmt == "cc":
                vals[slot, s.rows, local_c] = s.vals
            else:
                # sorted flat COO: row-major (row, local col) order gives the
                # segment-sums contiguous destination runs
                order = np.lexsort((local_c, s.rows))
                nz = s.nnz
                if nz > vals.shape[1]:
                    raise ValueError(
                        f"subject {k} has {nz} nonzeros > bucket N_pad "
                        f"{vals.shape[1]} (stale plan?)")
                rr, lc = s.rows[order], local_c[order]
                vals[slot, :nz] = s.vals[order]
                trip_rows[slot, :nz] = rr
                trip_lcols[slot, :nz] = lc
                # CSR/CSC-style boundaries for the scatter-free segment-sums
                row_ends[slot] = np.searchsorted(rr, np.arange(i_pad),
                                                 side="right")
                corder = np.lexsort((rr, lc)).astype(np.int32)
                cperm[slot, :nz] = corder
                col_ends[slot] = np.searchsorted(lc[corder], np.arange(c_pad),
                                                 side="right")
                nnz_n[slot] = nz
            cols[slot, : kept.size] = kept
            cmask[slot, : kept.size] = 1.0
            sids[slot] = k
            smask[slot] = 1.0
            rows_n[slot] = s.n_rows
        common = dict(
            cols=jnp.asarray(cols),
            col_mask=jnp.asarray(cmask, dtype=dtype),
            subject_ids=jnp.asarray(sids),
            subject_mask=jnp.asarray(smask, dtype=dtype),
            row_counts=jnp.asarray(rows_n),
        )
        if fmt == "cc":
            buckets.append(Bucket(vals=jnp.asarray(vals, dtype=dtype), **common))
        else:
            buckets.append(SparseBucket(
                vals=jnp.asarray(vals, dtype=dtype),
                rows=jnp.asarray(trip_rows),
                lcols=jnp.asarray(trip_lcols),
                row_ends=jnp.asarray(row_ends),
                cperm=jnp.asarray(cperm),
                col_ends=jnp.asarray(col_ends),
                nnz_counts=jnp.asarray(nnz_n),
                n_rows_pad=i_pad,
                **common,
            ))
    return Bucketed(
        buckets=buckets,
        n_subjects=data.n_subjects,
        n_cols=data.n_cols,
        norm_sq=data.frobenius_sq(),
    )


# ---------------------------------------------------------------------------
# BCC: block-compressed columns (Pallas kernel layout)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockBucket:
    """BCC layout: columns quantized to LANE-wide blocks of J.

    vals:     f[Kb, I_pad, NB, LANE]  dense values per kept column-block
    blk_ids:  i32[Kb, NB]             global block index (j // LANE) (pad: 0)
    blk_mask: f[Kb, NB]               1.0 for real blocks
    """

    vals: jax.Array
    blk_ids: jax.Array
    blk_mask: jax.Array
    subject_ids: jax.Array
    subject_mask: jax.Array

    def tree_flatten(self):
        return (self.vals, self.blk_ids, self.blk_mask, self.subject_ids, self.subject_mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def kb(self):
        return self.vals.shape[0]

    @property
    def i_pad(self):
        return self.vals.shape[1]

    @property
    def n_blocks(self):
        return self.vals.shape[2]


def to_block_bucket(b: Bucket, J: int, *, max_blocks: Optional[int] = None,
                    allow_truncate: bool = False) -> BlockBucket:
    """Host-side CC -> BCC conversion (column ids quantized to LANE blocks).

    ``max_blocks`` caps the per-subject block count; column-blocks beyond the
    cap DROP their nonzeros. That is data loss, so by default it raises
    ``ValueError`` with the dropped-nonzero count; pass
    ``allow_truncate=True`` to accept the loss (a ``UserWarning`` with the
    same count is emitted instead).
    """
    vals = np.asarray(b.vals)
    cols = np.asarray(b.cols)
    cmask = np.asarray(b.col_mask) > 0
    kb, i_pad, _ = vals.shape
    per_subject_blocks = []
    for k in range(kb):
        kept = cols[k][cmask[k]]
        per_subject_blocks.append(np.unique(kept // LANE) if kept.size else np.zeros((0,), np.int64))
    nb = max((blk.size for blk in per_subject_blocks), default=1)
    nb = max(nb, 1)
    if max_blocks is not None:
        nb = min(nb, max_blocks)
    out_vals = np.zeros((kb, i_pad, nb, LANE), dtype=vals.dtype)
    blk_ids = np.zeros((kb, nb), dtype=np.int32)
    blk_mask = np.zeros((kb, nb), dtype=vals.dtype)
    dropped_nnz = 0
    for k in range(kb):
        blocks = per_subject_blocks[k][:nb]
        pos = {int(bid): i for i, bid in enumerate(blocks)}
        blk_ids[k, : blocks.size] = blocks
        blk_mask[k, : blocks.size] = 1.0
        kept_idx = np.nonzero(cmask[k])[0]
        for ci in kept_idx:
            gcol = int(cols[k, ci])
            bslot = pos.get(gcol // LANE)
            if bslot is None:
                # column-block truncated by max_blocks: its nonzeros are lost
                dropped_nnz += int(np.count_nonzero(vals[k, :, ci]))
                continue
            out_vals[k, :, bslot, gcol % LANE] = vals[k, :, ci]
    if dropped_nnz:
        msg = (f"to_block_bucket(max_blocks={max_blocks}) truncated "
               f"{dropped_nnz} nonzeros (column-blocks beyond the cap); "
               f"raise max_blocks or pass allow_truncate=True to accept "
               f"the data loss")
        if not allow_truncate:
            raise ValueError(msg)
        warnings.warn(msg, UserWarning, stacklevel=2)
    return BlockBucket(
        vals=jnp.asarray(out_vals),
        blk_ids=jnp.asarray(blk_ids),
        blk_mask=jnp.asarray(blk_mask),
        subject_ids=b.subject_ids,
        subject_mask=b.subject_mask,
    )
