"""CP-ALS pieces: Gram utilities, column normalization, and a standalone
dense CP-ALS (used as a reference implementation and by tests).

The PARAFAC2 inner step runs exactly ONE CP-ALS iteration on the intermediate
tensor Y (Kiers et al.) — that iteration lives in repro.core.parafac2 and uses
the SPARTan MTTKRPs; here we keep the shared algebra.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.nnls import hals_nnls, ridge_solve

__all__ = ["normalize_columns", "cp_gram", "cp_als_dense", "factor_update"]


def normalize_columns(X: jax.Array, *, eps: float = 1e-12) -> Tuple[jax.Array, jax.Array]:
    """Unit-normalize columns; return (normalized, norms)."""
    norms = jnp.sqrt(jnp.sum(X * X, axis=0))
    safe = jnp.maximum(norms, eps)
    return X / safe, norms


def cp_gram(*factors: jax.Array) -> jax.Array:
    """Hadamard product of factor Grams: prod_i (F_i^T F_i)."""
    G = None
    for F in factors:
        FtF = F.T @ F
        G = FtF if G is None else G * FtF
    return G


def factor_update(M: jax.Array, gram: jax.Array, prev: jax.Array, *,
                  nonneg: bool, nnls_sweeps: int = 5) -> jax.Array:
    """One ALS factor update from its MTTKRP M and Gram matrix."""
    if nonneg:
        return hals_nnls(M, gram, prev, sweeps=nnls_sweeps)
    return ridge_solve(M, gram)


class CPState(NamedTuple):
    U: jax.Array
    V: jax.Array
    W: jax.Array
    lam: jax.Array


def cp_als_dense(
    X: jax.Array,
    rank: int,
    *,
    iters: int = 50,
    nonneg: bool = False,
    seed: int = 0,
    dtype=jnp.float32,
) -> CPState:
    """Plain dense CP-ALS on an I x J x K array (reference / tests only)."""
    I, J, K = X.shape
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    init = jax.random.uniform if nonneg else jax.random.normal
    U = init(k0, (I, rank), dtype)
    V = init(k1, (J, rank), dtype)
    W = jnp.ones((K, rank), dtype)
    X1 = X.reshape(I, J * K)                       # mode-1 unfolding (i, j*k)
    X2 = jnp.transpose(X, (1, 0, 2)).reshape(J, I * K)
    X3 = jnp.transpose(X, (2, 0, 1)).reshape(K, I * J)

    def kr(A, B):  # Khatri-Rao
        return (A[:, None, :] * B[None, :, :]).reshape(-1, A.shape[1])

    def body(state, _):
        U, V, W = state
        U = factor_update(X1 @ kr(W, V), cp_gram(W, V), U, nonneg=nonneg)
        U, _ = normalize_columns(U)
        V = factor_update(X2 @ kr(W, U), cp_gram(W, U), V, nonneg=nonneg)
        V, _ = normalize_columns(V)
        W = factor_update(X3 @ kr(V, U), cp_gram(V, U), W, nonneg=nonneg)
        return (U, V, W), None

    (U, V, W), _ = jax.lax.scan(body, (U, V, W), None, length=iters)
    W, lam = normalize_columns(W)
    return CPState(U=U, V=V, W=W, lam=lam)
