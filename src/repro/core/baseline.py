"""Baseline MTTKRP — the pre-SPARTan approach the paper compares against.

The Tensor-Toolbox baseline materializes the intermediate tensor Y (R x J x K)
and computes each MTTKRP via matricization x full Khatri-Rao product. We
reproduce that faithfully (dense Y + explicit KRP blocks) so the benchmarks can
measure the paper's claimed gap on identical inputs. Memory: O(R*J*K) for Y and
O(max(KJ, RK, RJ) * R) for the KRP — exactly the blow-up the paper eliminates.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.irregular import Bucket

__all__ = [
    "dense_y",
    "baseline_mode1",
    "baseline_mode2",
    "baseline_mode3",
    "khatri_rao",
]


def khatri_rao(A: jax.Array, B: jax.Array) -> jax.Array:
    """Column-wise Khatri-Rao product: [I,R] x [J,R] -> [I*J, R]."""
    I, R = A.shape
    J, _ = B.shape
    return (A[:, None, :] * B[None, :, :]).reshape(I * J, R)


def dense_y(buckets: List[Bucket], Ycs: List[jax.Array], J: int, K: int) -> jax.Array:
    """Materialize Y in R^{R x J x K} from per-bucket compressed slices."""
    R = Ycs[0].shape[1]
    Y = jnp.zeros((R, J, K), Ycs[0].dtype)
    for b, Yc in zip(buckets, Ycs):
        dense_k = b.scatter_cols_to_dense(Yc, J)            # [Kb, R, J]
        masked = dense_k * b.subject_mask[:, None, None]
        Y = Y.at[:, :, b.subject_ids].add(jnp.transpose(masked, (1, 2, 0)))
    return Y


def baseline_mode1(Y: jax.Array, V: jax.Array, W: jax.Array) -> jax.Array:
    """M1 = Y_(1) (W ⊙ V): mode-1 matricization x full KRP."""
    R, J, K = Y.shape
    Y1 = jnp.transpose(Y, (0, 2, 1)).reshape(R, K * J)       # [R, K*J]
    KR = khatri_rao(W, V)                                    # [K*J, R]
    return Y1 @ KR


def baseline_mode2(Y: jax.Array, H: jax.Array, W: jax.Array) -> jax.Array:
    """M2 = Y_(2) (W ⊙ H)."""
    R, J, K = Y.shape
    Y2 = jnp.transpose(Y, (1, 2, 0)).reshape(J, K * R)       # [J, K*R]
    KR = khatri_rao(W, H)                                    # [K*R, R]
    return Y2 @ KR


def baseline_mode3(Y: jax.Array, H: jax.Array, V: jax.Array) -> jax.Array:
    """M3 = Y_(3) (V ⊙ H)."""
    R, J, K = Y.shape
    Y3 = jnp.transpose(Y, (2, 1, 0)).reshape(K, J * R)       # [K, J*R]
    KR = khatri_rao(V, H)                                    # [J*R, R]
    return Y3 @ KR


def baseline_als_step(data, state, opts):
    """One PARAFAC2-ALS iteration with the BASELINE CP step: materialize the
    dense intermediate tensor Y (R x J x K) and run matricization x full-KRP
    MTTKRPs — the pre-SPARTan algorithm the paper benchmarks against.
    Procrustes/update algebra identical to repro.core.parafac2.als_step —
    including the same per-mode constraint bundle and carried ADMM aux state
    — so timing differences isolate the MTTKRP reformulation and
    SPARTan-vs-baseline comparisons stay apples-to-apples under any
    constraint spec."""
    from repro.core import constraints as cst
    from repro.core.cp import cp_gram, normalize_columns
    from repro.core.parafac2 import (
        Parafac2State, _procrustes_project, constraints_for)

    H, V, W = state.H, state.V, state.W
    R, J, K = opts.rank, data.n_cols, data.n_subjects
    cons = constraints_for(opts)
    solve_kw = dict(nnls_sweeps=opts.nnls_sweeps, admm_iters=opts.admm_iters)
    aux = state.aux if isinstance(state.aux, dict) else cst.empty_aux()
    per_bucket = [_procrustes_project(b, H, V, W, opts) for b in data.buckets]
    Ycs = [pb[0] for pb in per_bucket]
    Y = dense_y(data.buckets, Ycs, J, K)                     # the memory blow-up

    M1 = baseline_mode1(Y, V, W)
    H_new, aux_h = cons["h"].update(M1, cp_gram(W, V), H, aux["h"], **solve_kw)
    aux_w = aux["w"]
    if not cons["h"].penalized:     # same normalization rule as als_step
        H_new, h_norms = normalize_columns(H_new)
        aux_h = cst.scale_aux(aux_h, 1.0 / jnp.maximum(h_norms, 1e-12))
        W = W * h_norms[None, :]
        aux_w = cst.scale_aux(aux_w, h_norms)

    M2 = baseline_mode2(Y, H_new, W)
    V_new, aux_v = cons["v"].update(M2, cp_gram(W, H_new), V, aux["v"],
                                    **solve_kw)
    if not cons["v"].penalized:
        V_new, v_norms = normalize_columns(V_new)
        aux_v = cst.scale_aux(aux_v, 1.0 / jnp.maximum(v_norms, 1e-12))
        W = W * v_norms[None, :]
        aux_w = cst.scale_aux(aux_w, v_norms)

    M3 = baseline_mode3(Y, H_new, V_new)
    gram3 = (V_new.T @ V_new) * (H_new.T @ H_new)
    W_new, aux_w = cons["w"].update(M3, gram3, W, aux_w, **solve_kw)

    Phi = H_new.T @ H_new
    VtV = V_new.T @ V_new
    resid = jnp.asarray(data.norm_sq, opts.dtype)
    G_all = jnp.einsum("rjk,jl->krl", Y, V_new)
    cross = jnp.einsum("rl,krl,kl->", H_new, G_all, W_new)
    model = jnp.einsum("rl,rl,kr,kl->", Phi, VtV, W_new, W_new)
    resid = resid - 2.0 * cross + model
    fit_val = 1.0 - jnp.sqrt(jnp.maximum(resid, 0.0)) / jnp.sqrt(
        jnp.asarray(data.norm_sq, opts.dtype))
    return Parafac2State(H=H_new, V=V_new, W=W_new, fit=fit_val,
                         aux={"h": aux_h, "v": aux_v, "w": aux_w})
