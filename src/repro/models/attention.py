"""Attention: GQA with RoPE / qk-norm / sliding-window, in three lowerings.

* ``attend_train``  — memory-bounded chunked (flash-style online-softmax over
  key blocks, pure JAX scan) causal attention. Activation memory is O(S * Bq)
  instead of O(S^2), which is what makes the 32k-prefill shapes lowerable with
  a credible memory footprint.
* ``attend_decode`` — single-query attention against a KV cache.
* cross-attention (whisper) reuses the chunked path without the causal mask.

All functions are batched [B, S, H, D] and GQA-aware (n_kv <= n_heads;
q heads grouped over kv heads). No dropout (pretraining-style).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, rmsnorm
from repro.dist.sharding import shard

__all__ = ["attend_train", "attend_decode", "AttnParams", "init_attn", "attn_block"]

NEG_INF = -1e30


def _gqa_expand(k: jax.Array, groups: int) -> jax.Array:
    """[B, S, KV, D] -> [B, S, KV*groups, D] by repeating kv heads."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attend_train(
    q: jax.Array,               # [B, Sq, H, D]
    k: jax.Array,               # [B, Skv, KV, D]
    v: jax.Array,               # [B, Skv, KV, D]
    *,
    causal: bool = True,
    window: int = 0,            # sliding window (0 = full)
    q_offset: int = 0,          # absolute position of q[0] relative to k[0]
    block_kv: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention (flash-style, pure JAX)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    groups = H // KV
    k = _gqa_expand(k, groups)
    v = _gqa_expand(v, groups)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32) * scale

    nb = max(1, (Skv + block_kv - 1) // block_kv)
    pad = nb * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block_kv, H, D)
    vb = v.reshape(B, nb, block_kv, H, D)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, bidx = blk
        k_pos = bidx * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk.astype(jnp.float32))
        mask = k_pos[None, :] <= Skv - 1  # drop padded keys
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    blks = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb))
    from repro.dist.sharding import unroll_active

    if unroll_active():
        carry = (m0, l0, acc0)
        for i in range(nb):
            carry, _ = body(carry, jax.tree_util.tree_map(lambda a: a[i], blks))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), blks)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)   # [B, Sq, H, D]


def attend_decode(
    q: jax.Array,               # [B, 1, H, D]
    k_cache: jax.Array,         # [B, Skv, KV, D]
    v_cache: jax.Array,
    *,
    length: jax.Array,          # [B] valid cache lengths (new token already in)
    window: int = 0,
) -> jax.Array:
    B, _, H, D = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    groups = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = (q.astype(jnp.float32) * scale).reshape(B, H, D)
    qg = qf.reshape(B, KV, groups, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(Skv)
    mask = pos[None, :] < length[:, None]
    if window:
        mask = mask & (pos[None, :] >= length[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (qkv proj + rope + attend + out proj)
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype) -> dict:
    from repro.models.common import dense_init

    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), scale=1.0 / jnp.sqrt(H * hd * 2.0 * max(cfg.n_layers, 1)), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.zeros((hd,), dtype)
        p["k_norm_scale"] = jnp.zeros((hd,), dtype)
    return p


def attn_block(
    p: dict,
    x: jax.Array,                       # [B, S, d]
    cfg,
    *,
    positions: jax.Array,               # [S] or [B, S]
    causal: bool = True,
    window: int = 0,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # decode
    cache_length: Optional[jax.Array] = None,
    cache_index: Optional[jax.Array] = None,                  # scalar write slot
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # enc-dec
    use_rope: bool = True,
):
    """Returns (out [B,S,d], new_kv_cache or None)."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    q = shard(q, ("batch", "seq", "heads", None))
    if cross_kv is None:
        k = (x @ p["wk"]).reshape(B, S, KV, hd)
        v = (x @ p["wv"]).reshape(B, S, KV, hd)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm_scale"], cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(k, p["k_norm_scale"], cfg.norm_eps)
    if use_rope and cross_kv is None:
        if positions.ndim == 1:
            positions = positions[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        # decode: write this step's k/v into the cache ring
        kc, vc = kv_cache
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, cache_index, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, cache_index, 0, 0))
        new_cache = (kc, vc)
        out = attend_decode(q, kc, vc, length=cache_length, window=window)
    elif cross_kv is not None:
        out = attend_train(q, k, v, causal=False)
    else:
        out = attend_train(q, k, v, causal=causal, window=window)
    out = shard(out, ("batch", "seq", "heads", None))
    y = out.reshape(B, S, H * hd) @ p["wo"]
    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "block_out")
    return shard(y, ("batch", "seq_res", "embed")), new_cache
