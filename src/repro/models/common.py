"""Shared model primitives: initializers, norms, RoPE, activations."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "rmsnorm",
    "layernorm",
    "rope_freqs",
    "apply_rope",
    "act_fn",
    "cast",
]


def dense_init(key, shape, *, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[0] if len(shape) == 1 else shape[-2]
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                                   # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv         # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(name: str):
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name}")


def cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
