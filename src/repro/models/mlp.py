"""Dense MLP blocks: SwiGLU (llama-family) and GELU (whisper/older)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init
from repro.dist.sharding import shard

__all__ = ["init_mlp", "mlp_block"]


def init_mlp(key, cfg, dtype, d_ff=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    down_scale = 1.0 / jnp.sqrt(f * 2.0 * max(cfg.n_layers, 1))
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype=dtype),
            "w_up": dense_init(ks[1], (d, f), dtype=dtype),
            "w_down": dense_init(ks[2], (f, d), scale=down_scale, dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), dtype=dtype),
        "w_down": dense_init(ks[1], (f, d), scale=down_scale, dtype=dtype),
    }


def mlp_block(p: dict, x: jax.Array, cfg) -> jax.Array:
    act = act_fn(cfg.act)
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    h = shard(h, ("batch", "seq", "mlp"))
    y = h @ p["w_down"]
    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "block_out")
    return shard(y, ("batch", "seq_res", "embed"))
