"""Public model API: build train/prefill/decode step functions + input specs.

`build(cfg)` returns a :class:`ModelBundle` whose step functions are pure
(params/opt-state in, params/opt-state out) and whose ``input_specs(shape)``
produce ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES
from repro.models.transformer import init_cache, init_lm, lm_decode, lm_forward
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import wsd_schedule
from repro.dist.sharding import shard

__all__ = ["ModelBundle", "build", "cross_entropy"]

AUX_COEF = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array, *, ignore: int = -1):
    """Mean CE over valid labels; logits [B,S,V] (any float dtype), labels [B,S].

    Sharded-vocab safe: the gold logit is extracted with a masked sum over the
    vocab axis (partitions cleanly into a shard-local reduction + psum) instead
    of take_along_axis, which would force an all-gather of the full logits.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    onehot = (vocab_ids == jnp.maximum(labels, 0)[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    mask = (labels != ignore).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init_params: Callable[[jax.Array], Any]
    init_opt: Callable[[Any], Any]
    train_step: Callable[..., Tuple[Any, Any, Dict[str, jax.Array]]]
    prefill_step: Callable[..., jax.Array]
    decode_step: Callable[..., Tuple[jax.Array, Any]]
    input_specs: Callable[[str], Dict[str, Any]]
    init_cache: Callable[[int, int], Any]


def _extra_inputs(cfg: ArchConfig, batch: int, dtype) -> Dict[str, Any]:
    """Modality-stub inputs (precomputed frame/patch embeddings)."""
    out = {}
    if cfg.is_encdec:
        out["encoder_frames"] = (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.n_prefix_embeds:
        out["prefix_embeds"] = (batch, cfg.n_prefix_embeds, cfg.d_model)
    return out


def build(cfg: ArchConfig, *, lr: float = 3e-4, wd: float = 0.1,
          total_steps: int = 10_000, microbatches: int = 1) -> ModelBundle:
    """``microbatches > 1`` enables gradient accumulation: the global batch is
    split along dim 0 into n sequential micro-steps whose f32 grads average —
    activation/stash memory scales ~1/n at unchanged math (one optimizer
    update per step; grad all-reduce once, after accumulation)."""
    dtype = jnp.dtype(cfg.dtype)
    sched = wsd_schedule(peak=lr, warmup=max(1, total_steps // 100),
                         total=total_steps, decay_frac=0.1)

    def init_params(rng):
        return init_lm(rng, cfg)

    def loss_fn(params, batch):
        extra = {k: batch[k] for k in ("encoder_frames", "prefix_embeds") if k in batch}
        logits, aux = lm_forward(params, batch["tokens"], cfg, **extra)
        loss = cross_entropy(logits, batch["labels"])
        return loss + AUX_COEF * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _grads(params, batch):
        if microbatches <= 1:
            (total, (ce, aux)), grads = grad_fn(params, batch)
            return total, ce, aux, grads

        def slice_mb(i, leaf):
            mb = leaf.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(leaf, i * mb, mb, axis=0)

        def body(carry, i):
            acc, tot, ce, aux = carry
            mb_batch = jax.tree_util.tree_map(lambda l: slice_mb(i, l), batch)
            (t, (c, a)), g = grad_fn(params, mb_batch)
            acc = jax.tree_util.tree_map(
                lambda s, x: s + x.astype(jnp.float32), acc, g)
            return (acc, tot + t, ce + c, aux + a), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, tot, ce, aux), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0, 0.0), jnp.arange(microbatches))
        n = float(microbatches)
        grads = jax.tree_util.tree_map(lambda g: g / n, acc)
        return tot / n, ce / n, aux / n, grads

    def train_step(params, opt_state, batch, step):
        total, ce, aux, grads = _grads(params, batch)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=sched(step), wd=wd)
        metrics = {"loss": ce, "aux": aux, "total": total}
        return params, opt_state, metrics

    def prefill_step(params, batch):
        extra = {k: batch[k] for k in ("encoder_frames", "prefix_embeds") if k in batch}
        logits, _ = lm_forward(params, batch["tokens"], cfg, **extra)
        return logits

    def decode_step(params, cache, tokens, pos):
        return lm_decode(params, cache, tokens, cfg, pos=pos)

    def _cache(batch, max_len):
        return init_cache(cfg, batch, max_len, dtype=jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def input_specs(shape_name: str) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        spec = SHAPES[shape_name] if isinstance(shape_name, str) else shape_name
        B, S = spec.global_batch, spec.seq_len
        i32 = jnp.int32
        f = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if spec.kind == "train":
            out = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
            for k, shp in _extra_inputs(cfg, B, f).items():
                out[k] = sds(shp, f)
            return {"batch": out, "step": sds((), i32)}
        if spec.kind == "prefill":
            out = {"tokens": sds((B, S), i32)}
            for k, shp in _extra_inputs(cfg, B, f).items():
                out[k] = sds(shp, f)
            return {"batch": out}
        # decode: KV/state cache of seq_len, one new token
        cache = jax.eval_shape(lambda: _cache(B, S))
        return {
            "cache": cache,
            "tokens": sds((B, 1), i32),
            "pos": sds((), i32),
        }

    return ModelBundle(
        cfg=cfg,
        init_params=init_params,
        init_opt=adamw_init,
        train_step=train_step,
        prefill_step=prefill_step,
        decode_step=decode_step,
        input_specs=input_specs,
        init_cache=_cache,
    )
