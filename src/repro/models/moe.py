"""Mixture-of-Experts: token-choice top-k routing with sort-based dispatch.

Dispatch is the static-shape sort algorithm (no [T, E, C] one-hot blow-up):
flatten (token, expert) assignments, stable-sort by expert, rank within each
expert group via searchsorted, drop tokens beyond capacity, scatter into a
[E, capacity, d] buffer, grouped-matmul all experts at once (E sharded over
the "model" axis = expert parallelism), and combine with router gates.
Capacity = ceil(T * k / E * capacity_factor) — standard token dropping.

Aux load-balance loss (Switch-style) is returned for the train loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init
from repro.dist.sharding import shard

__all__ = ["init_moe", "moe_block"]


def init_moe(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    down_scale = 1.0 / jnp.sqrt(f * 2.0 * max(cfg.n_layers, 1))
    p = {
        "router": {"w": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32)},
        "experts": {
            "w_gate": dense_init(ks[1], (E, d, f), dtype=dtype),
            "w_up": dense_init(ks[2], (E, d, f), dtype=dtype),
            "w_down": dense_init(ks[3], (E, f, d), scale=down_scale, dtype=dtype),
        },
    }
    if cfg.shared_expert:
        from repro.models.mlp import init_mlp

        p["shared"] = init_mlp(ks[4], cfg, dtype)
    return p


def moe_block(p: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux_loss scalar).

    Under an active mesh with a "model" axis, dispatch/combine run in a
    manual shard_map with explicit `lax.all_to_all` exchanges (the production
    EP pattern — GSPMD cannot turn data-dependent gathers into all-to-alls and
    falls back to full all-gathers, measured 10-60x more collective bytes).
    Otherwise the pure-GSPMD path below runs (single device, smoke tests).
    """
    from repro.dist.sharding import current_mesh, current_rules

    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.n_experts % _axis_len(mesh, "model") == 0
            and _axis_len(mesh, "model") > 1
            and x.shape[1] % _axis_len(mesh, "model") == 0):
        return _moe_block_manual(p, x, cfg, mesh)
    return _moe_block_auto(p, x, cfg)


def _axis_len(mesh, name):
    return mesh.devices.shape[mesh.axis_names.index(name)]


def _moe_block_auto(p: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ p["router"]["w"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- flatten assignments and sort by expert --------------------------
    Tk = T * k
    flat_expert = expert_idx.reshape(Tk)
    flat_gate = gate_vals.reshape(Tk)
    flat_token = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    capacity = max(8, int(round(T * k * cfg.capacity_factor / E + 0.5)))
    # rank within expert group (first-occurrence trick on the sorted array)
    first = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    rank = jnp.arange(Tk) - first
    keep = rank < capacity
    dest = jnp.where(keep, sorted_expert * capacity + rank, E * capacity)

    # ---- dispatch (gather-only; §Perf 'moe gather dispatch') ---------------
    # d-wide data moves are expressed exclusively as jnp.take gathers; the
    # only scatters touch int32 slot maps (no trailing d width), which GSPMD
    # SPMD-ifies without materializing [T*k, d]-wide index tensors.
    token_for_slot = jnp.full((E * capacity,), -1, jnp.int32)
    token_for_slot = token_for_slot.at[dest].set(sorted_token.astype(jnp.int32),
                                                 mode="drop")
    slot_valid = token_for_slot >= 0
    hidden_flat = jnp.take(xt, jnp.maximum(token_for_slot, 0), axis=0)
    hidden_flat = jnp.where(slot_valid[:, None], hidden_flat, 0)
    hidden_flat = shard(hidden_flat, ("expert_cap", "embed"))
    hidden_in = hidden_flat.reshape(E, capacity, d)
    hidden_in = shard(hidden_in, ("experts", "batch", "embed"))

    # ---- grouped expert matmuls (E on the "model" axis = EP) --------------
    act = act_fn(cfg.act)
    w = p["experts"]
    h = act(jnp.einsum("ecd,edf->ecf", hidden_in, w["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", hidden_in, w["w_up"])
    h = shard(h, ("experts", "batch", None))   # e on model, capacity on dp
    y = jnp.einsum("ecf,efd->ecd", h, w["w_down"])
    y = shard(y, ("experts", "batch", "embed"))

    # ---- combine back to tokens (gather-only) ------------------------------
    src = shard(y.reshape(E * capacity, d), ("expert_cap", "embed"))
    # slot index for each (token, k) assignment, in token order: invert the
    # sort with a gather (inverse permutation), not a scatter.
    inv_order = jnp.argsort(order, stable=True)
    slot_token_order = jnp.where(keep, dest, E * capacity)[inv_order]   # [Tk]
    took = jnp.take(src, jnp.minimum(slot_token_order, E * capacity - 1), axis=0)
    took = jnp.where((slot_token_order < E * capacity)[:, None], took, 0)
    took = shard(took, ("tokens", "embed"))
    # combine in bf16: the [T*k, d] gathers (and their scatter-add cotangents)
    # are the dominant collective payload — f32 here doubles DCN/ICI bytes.
    contrib = took * flat_gate[:, None].astype(took.dtype)
    out = contrib.reshape(T, k, d).sum(axis=1)
    out = shard(out, ("tokens", "embed"))

    if "shared" in p:
        from repro.models.mlp import mlp_block

        out = out + mlp_block(p["shared"], x, cfg).reshape(T, d).astype(out.dtype)

    # ---- Switch-style load-balance aux loss -------------------------------
    me = probs.mean(axis=0)                                        # [E] router mass
    ce = jnp.zeros((E,), jnp.float32).at[flat_expert].add(1.0) / Tk
    aux = E * jnp.sum(me * ce)

    return out.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Manual expert-parallel path: shard_map + lax.all_to_all
# ---------------------------------------------------------------------------

def _moe_block_manual(p: dict, x: jax.Array, cfg, mesh) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E, k = cfg.n_experts, cfg.experts_per_token
    d = x.shape[-1]
    tp = _axis_len(mesh, "model")
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= _axis_len(mesh, a)
    all_axes = dp_axes + ("model",)
    E_loc = E // tp
    B, S, _ = x.shape

    act = act_fn(cfg.act)

    def local_moe(xb, wr, wg, wu, wd):
        # xb [B_loc, S_loc, d] local; wr [d, E]; wg/wu [E_loc, d, f]; wd [E_loc, f, d]
        Bl, Sl, _ = xb.shape
        Tl = Bl * Sl
        # per-device capacity from the LOCAL token count (shapes are static)
        cap_loc = max(8, -(-Tl * k * int(round(cfg.capacity_factor * 4)) // (4 * E)))
        xt = xb.reshape(Tl, d)
        logits = xt.astype(jnp.float32) @ wr
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
        Tk = Tl * k
        flat_expert = expert_idx.reshape(Tk)
        flat_gate = gate_vals.reshape(Tk)
        flat_token = jnp.repeat(jnp.arange(Tl), k)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        sorted_token = flat_token[order]
        first = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
        rank = jnp.arange(Tk) - first
        keep = rank < cap_loc
        dest = jnp.where(keep, sorted_expert * cap_loc + rank, E * cap_loc)

        token_for_slot = jnp.full((E * cap_loc,), -1, jnp.int32)
        token_for_slot = token_for_slot.at[dest].set(
            sorted_token.astype(jnp.int32), mode="drop")
        valid = token_for_slot >= 0
        hidden = jnp.take(xt, jnp.maximum(token_for_slot, 0), axis=0)
        hidden = jnp.where(valid[:, None], hidden, 0)

        # exchange: tokens -> expert owners along the model axis
        send = hidden.reshape(tp, E_loc, cap_loc, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=2,
                                  tiled=True)                  # [E_loc, tp*cap_loc, d]? (tiled)
        recv = recv.reshape(E_loc, tp * cap_loc, d)

        h = act(jnp.einsum("ecd,edf->ecf", recv, wg))
        h = h * jnp.einsum("ecd,edf->ecf", recv, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)                  # [E_loc, tp*cap_loc, d]

        # reverse exchange: results back to token owners
        yb = y.reshape(E_loc, tp, cap_loc, d)
        back = jax.lax.all_to_all(yb, "model", split_axis=1, concat_axis=0,
                                  tiled=True)                  # [tp*E_loc, cap_loc, d]
        src = back.reshape(E * cap_loc, d)

        inv_order = jnp.argsort(order, stable=True)
        slot_token_order = jnp.where(keep, dest, E * cap_loc)[inv_order]
        took = jnp.take(src, jnp.minimum(slot_token_order, E * cap_loc - 1), axis=0)
        took = jnp.where((slot_token_order < E * cap_loc)[:, None], took, 0)
        contrib = took * flat_gate[:, None].astype(took.dtype)
        out = contrib.reshape(Tl, k, d).sum(axis=1)

        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[flat_expert].add(1.0) / Tk
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, all_axes)
        return out.reshape(Bl, Sl, d).astype(xb.dtype), aux

    # tokens split over BOTH dp (batch) and model (sequence) axes — otherwise
    # every model-peer dispatches the same tokens (tp x duplicated compute
    # and exchange traffic; measured 11x compute regression, see §Perf log).
    batch_spec = P(dp_axes if dp > 1 and B % dp == 0 else None, "model", None)
    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(batch_spec, P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(batch_spec, P()),
        check_rep=False,
    )
    out, aux = fn(x, p["router"]["w"], p["experts"]["w_gate"],
                  p["experts"]["w_up"], p["experts"]["w_down"])
    if "shared" in p:
        from repro.models.mlp import mlp_block

        out = out + mlp_block(p["shared"], x, cfg).astype(out.dtype)
    return out, aux
