"""Mamba2 SSD mixer — chunked state-space-duality algorithm (arXiv:2405.21060).

The SSD recurrence per head (scalar-a, state N, head dim P):
    h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t)        h in R^{P x N}
    y_t = C_t · h_t + D * x_t

Chunked form (chunk length Lc) — the TPU-friendly matmul decomposition:
  * intra-chunk: quadratic "attention-like" term  L ⊙ (C B^T) @ (dt·x)
  * chunk states: per-chunk summary  S_c = Σ_j decay_j B_j ⊗ (dt x)_j
  * inter-chunk: tiny sequential scan over n_chunks states
  * output correction: y += decay_i * C_i · h_{c-1}

Decode is the O(1) recurrence on a carried [B, H, P, N] state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.dist.sharding import shard

__all__ = ["init_mamba", "mamba_block", "init_mamba_cache", "ssd_chunked", "ssd_reference"]


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    N = cfg.ssm_state
    return d_in, H, P, N


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    ks = jax.random.split(key, 8)
    # STREAM-SEPARATE projections and convs (z, x, B, C, dt): a fused
    # projection's split boundaries cross the model-axis tiling and force
    # collective-permute realignments every layer (§Perf: measured 1.3 GiB of
    # permutes per layer on mamba2 train); separate weights shard cleanly.
    return {
        "in_proj_z": dense_init(ks[0], (d, d_in), dtype=dtype),
        "in_proj_x": dense_init(ks[1], (d, d_in), dtype=dtype),
        "in_proj_B": dense_init(ks[2], (d, N), dtype=dtype),
        "in_proj_C": dense_init(ks[3], (d, N), dtype=dtype),
        "in_proj_dt": dense_init(ks[4], (d, H), dtype=dtype),
        "conv": {"wx": dense_init(ks[5], (cfg.conv_width, d_in), dtype=dtype),
                 "bx": jnp.zeros((d_in,), dtype),
                 "wB": dense_init(ks[6], (cfg.conv_width, N), dtype=dtype),
                 "bB": jnp.zeros((N,), dtype),
                 "wC": dense_init(ks[7], (cfg.conv_width, N), dtype=dtype),
                 "bC": jnp.zeros((N,), dtype)},
        "A_log": jnp.zeros((H,), jnp.float32),      # a = exp(-softplus(A_log)*dt)
        "dt_bias": jnp.full((H,), -4.6, jnp.float32),  # softplus^-1(0.01)-ish
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d), scale=1.0 / jnp.sqrt(d_in * 2.0 * max(cfg.n_layers, 1)), dtype=dtype),
        "norm_scale": jnp.zeros((d_in,), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv. x [B,S,C]; w [W,C]. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = ctx[:, -(W - 1):, :] if W > 1 else jnp.zeros_like(x[:, :0])
    return jax.nn.silu(y + b[None, None, :]), new_state


def ssd_reference(xdt, a, Bm, Cm):
    """Naive sequential SSD (oracle for tests). xdt [B,S,H,P]; a [B,S,H];
    Bm/Cm [B,S,N]. Returns y [B,S,H,P]."""
    Bsz, S, H, P = xdt.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, t):
        xt, at, bt, ct = t
        h = at[..., None, None] * h + xt[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xdt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def ssd_chunked(xdt, a, Bm, Cm, chunk: int,
                h_init: Optional[jax.Array] = None):
    """Chunked SSD. Shapes as ssd_reference. Returns (y, h_final)."""
    Bsz, S, H, P = xdt.shape
    N = Bm.shape[-1]
    Lc = min(chunk, S)
    pad = (-S) % Lc
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nC = (S + pad) // Lc
    f32 = jnp.float32
    xc = xdt.reshape(Bsz, nC, Lc, H, P).astype(f32)
    ac = a.reshape(Bsz, nC, Lc, H).astype(f32)
    bc = Bm.reshape(Bsz, nC, Lc, N).astype(f32)
    cc = Cm.reshape(Bsz, nC, Lc, N).astype(f32)

    la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-30)), axis=2)      # [B,nC,Lc,H]
    # intra-chunk: scores[i,j] = exp(la_i - la_j) * (C_i · B_j), j <= i
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]             # [B,nC,i,j,H]
    causal = jnp.tril(jnp.ones((Lc, Lc), bool))
    decay_ij = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)                    # [B,nC,i,j]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay_ij, xc)

    # chunk summary states: S_c = Σ_j exp(la_last - la_j) B_j ⊗ xdt_j
    last = la[:, :, -1:, :]                                        # [B,nC,1,H]
    decay_tail = jnp.exp(last - la)                                # [B,nC,Lc,H]
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, decay_tail, xc)

    # inter-chunk scan over nC states: h_c = exp(la_last_c) h_{c-1} + S_c
    a_chunk = jnp.exp(last[:, :, 0, :])                            # [B,nC,H]
    h0 = (h_init.astype(f32) if h_init is not None
          else jnp.zeros((Bsz, H, P, N), f32))

    def step(h, t):
        ac_, sc_ = t
        h_prev = h
        h = ac_[..., None, None] * h + sc_
        return h, h_prev

    (h_fin), h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                          # [B,nC,H,P,N]

    # inter-chunk output: y += exp(la_i) * C_i · h_{c-1}
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cc, jnp.exp(la), h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S + pad, H, P)[:, :S]
    return y, h_fin


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32):
    d_in, H, P, N = _dims(cfg)
    w = cfg.conv_width - 1
    return {
        "conv_x": jnp.zeros((batch, w, d_in), dtype),
        "conv_B": jnp.zeros((batch, w, N), dtype),
        "conv_C": jnp.zeros((batch, w, N), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba_block(p: dict, x: jax.Array, cfg, *, cache: Optional[dict] = None):
    """Mamba2 mixer. Train/prefill: chunked SSD. Decode (S==1): O(1) update.

    Returns (y [B,S,d], new_cache or None).
    """
    Bsz, S, d = x.shape
    d_in, H, P, N = _dims(cfg)
    z = x @ p["in_proj_z"]
    xs = x @ p["in_proj_x"]
    Bc = x @ p["in_proj_B"]
    Cc = x @ p["in_proj_C"]
    dt = x @ p["in_proj_dt"]
    xs = shard(xs, ("batch", "seq", "mlp"))

    new_cache = None
    if cache is not None and S == 1:
        xs, st_x = _causal_conv(xs, p["conv"]["wx"], p["conv"]["bx"], state=cache["conv_x"])
        Bc, st_B = _causal_conv(Bc, p["conv"]["wB"], p["conv"]["bB"], state=cache["conv_B"])
        Cc, st_C = _causal_conv(Cc, p["conv"]["wC"], p["conv"]["bC"], state=cache["conv_C"])
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,1,H]
        a = jnp.exp(-jax.nn.softplus(p["A_log"]) * dt_s)               # [B,1,H]
        xh = xs.reshape(Bsz, 1, H, P).astype(jnp.float32) * dt_s[..., None]
        h = cache["ssm"]
        h = a[:, 0, :, None, None] * h + xh[:, 0, :, :, None] * Bc.astype(jnp.float32)[:, 0, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32)[:, 0])
        y = y[:, None] + p["D"][None, None, :, None] * xs.reshape(Bsz, 1, H, P).astype(jnp.float32)
        new_cache = {"conv_x": st_x.astype(cache["conv_x"].dtype),
                     "conv_B": st_B.astype(cache["conv_B"].dtype),
                     "conv_C": st_C.astype(cache["conv_C"].dtype), "ssm": h}
    else:
        xs, st_x = _causal_conv(xs, p["conv"]["wx"], p["conv"]["bx"])
        Bc, st_B = _causal_conv(Bc, p["conv"]["wB"], p["conv"]["bB"])
        Cc, st_C = _causal_conv(Cc, p["conv"]["wC"], p["conv"]["bC"])
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
        a = jnp.exp(-jax.nn.softplus(p["A_log"]) * dt_s)
        xh = xs.reshape(Bsz, S, H, P).astype(jnp.float32) * dt_s[..., None]
        xh = shard(xh, ("batch", "seq", "heads", None))
        y, h_fin = ssd_chunked(xh, a, Bc, Cc, cfg.ssm_chunk)
        y = y + p["D"][None, None, :, None] * xs.reshape(Bsz, S, H, P).astype(jnp.float32)
        if cache is not None:  # prefill that seeds a decode cache
            new_cache = {"conv_x": st_x.astype(cache["conv_x"].dtype),
                         "conv_B": st_B.astype(cache["conv_B"].dtype),
                         "conv_C": st_C.astype(cache["conv_C"].dtype), "ssm": h_fin}

    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    # gated RMSNorm (mamba2's norm-before-out-proj, gated by z)
    from repro.models.common import rmsnorm

    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return shard(out, ("batch", "seq_res", "embed")), new_cache
