"""Unified decoder stack: pattern-based blocks + scan-over-groups.

Every architecture is a repeating ``pattern`` of block kinds:
  dense        ("attn_mlp",)
  qwen3        ("attn_mlp",) + qk_norm
  phi3.5-moe   ("attn_moe",)
  llama4       ("attn_mlp", "attn_moe")          # interleaved MoE
  recurrentgemma ("rglru", "rglru", "attn_local")
  mamba2       ("mamba",)
  whisper dec  ("attn_cross_mlp",)

The layer loop is `lax.scan` over `n_layers // len(pattern)` groups (stacked
params, compact HLO, optional remat per group); remainder layers run unrolled
at the tail. KV/recurrent caches are pytrees stacked the same way, so decode
steps scan over (param, cache) slices and emit updated caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import barrier, shard
from repro.models.common import dense_init, rmsnorm
from repro.models.attention import attn_block, init_attn
from repro.models.mlp import init_mlp, mlp_block
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import init_mamba, init_mamba_cache, mamba_block
from repro.models.rglru import init_rglru, init_rglru_cache, rglru_block

__all__ = [
    "default_pattern",
    "init_stack",
    "stack_forward",
    "init_cache",
    "stack_decode",
    "init_lm",
    "lm_forward",
    "lm_decode",
]

ATTN_KINDS = ("attn_mlp", "attn_local", "attn_moe", "attn_cross_mlp", "enc_attn_mlp")


def default_pattern(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.block_pattern:
        return cfg.block_pattern
    if cfg.family == "ssm":
        return ("mamba",)
    if cfg.family == "moe" and cfg.n_experts:
        return ("attn_moe",)
    return ("attn_mlp",)


# ---------------------------------------------------------------------------
# Per-kind init / apply
# ---------------------------------------------------------------------------

def init_block(kind: str, key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn_mlp", "attn_local", "enc_attn_mlp"):
        return {"ln1_scale": jnp.zeros((d,), dtype), "attn": init_attn(ks[0], cfg, dtype),
                "ln2_scale": jnp.zeros((d,), dtype), "mlp": init_mlp(ks[1], cfg, dtype)}
    if kind == "attn_moe":
        return {"ln1_scale": jnp.zeros((d,), dtype), "attn": init_attn(ks[0], cfg, dtype),
                "ln2_scale": jnp.zeros((d,), dtype), "moe": init_moe(ks[1], cfg, dtype)}
    if kind == "attn_cross_mlp":
        return {"ln1_scale": jnp.zeros((d,), dtype), "attn": init_attn(ks[0], cfg, dtype),
                "lnx_scale": jnp.zeros((d,), dtype), "cross": init_attn(ks[1], cfg, dtype),
                "ln2_scale": jnp.zeros((d,), dtype), "mlp": init_mlp(ks[2], cfg, dtype)}
    if kind == "mamba":
        return {"ln1_scale": jnp.zeros((d,), dtype), "mamba": init_mamba(ks[0], cfg, dtype)}
    if kind == "rglru":
        return {"ln1_scale": jnp.zeros((d,), dtype), "rec": init_rglru(ks[0], cfg, dtype),
                "ln2_scale": jnp.zeros((d,), dtype), "mlp": init_mlp(ks[1], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind}")


def apply_block(
    kind: str,
    p: Dict[str, Any],
    x: jax.Array,
    cfg: ArchConfig,
    ctx: Dict[str, Any],
    cache: Optional[Dict[str, Any]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    # SP boundary: the seq all-gather happens on the bf16 norm output (pinning
    # it on the residual itself makes GSPMD propagate the full-seq layout into
    # the whole stream — measured 3.7x memory regression, see §Perf log).
    h = rmsnorm(x, p["ln1_scale"], cfg.norm_eps)
    h = shard(h, ("batch", "seq", "embed"))
    window = cfg.local_window if kind == "attn_local" else 0
    causal = kind != "enc_attn_mlp"
    if kind in ATTN_KINDS:
        kv = cache.get("self") if cache else None
        cache_length, cache_slot, decode_window = ctx.get("cache_length"), ctx.get("cache_slot"), 0
        if kv is not None and kind == "attn_local" and window:
            # ring buffer: cache holds only the last `window` keys; slot wraps,
            # validity count saturates, and no extra window mask is needed.
            W = kv[0].shape[1]
            pos = ctx["pos"]
            cache_slot = pos % W
            cache_length = jnp.broadcast_to(jnp.minimum(pos + 1, W), (x.shape[0],))
        elif kv is None:
            decode_window = 0
        y, new_self = attn_block(
            p["attn"], h, cfg,
            positions=ctx["positions"], causal=causal, window=window if kv is None else decode_window,
            kv_cache=kv, cache_length=cache_length,
            cache_index=cache_slot,
        )
        x = x + y
        new_cache = {"self": new_self} if new_self is not None else ({} if cache else None)
        if kind == "attn_cross_mlp":
            hx = rmsnorm(x, p["lnx_scale"], cfg.norm_eps)
            cross_kv = cache.get("cross") if cache else ctx.get("cross_kv_fn")(p["cross"])
            y, _ = attn_block(p["cross"], hx, cfg, positions=ctx["positions"],
                              cross_kv=cross_kv, use_rope=False)
            x = x + y
            if new_cache is not None:
                new_cache["cross"] = cross_kv
        h2 = rmsnorm(x, p["ln2_scale"], cfg.norm_eps)
        h2 = shard(h2, ("batch", "seq", "embed"))   # SP boundary on bf16
        if kind == "attn_moe":
            # barrier: keep the bf16 cast of h2 on THIS side of the dispatch
            # gathers (XLA otherwise hoists the f32->bf16 convert past the
            # all-gather, doubling dispatch bytes).
            y, aux = moe_block(p["moe"], barrier(h2), cfg)
        else:
            y = mlp_block(p["mlp"], h2, cfg)
        x = x + y
        return x, new_cache, aux
    if kind == "mamba":
        y, new_cache = mamba_block(p["mamba"], h, cfg, cache=cache)
        return x + y, new_cache, aux
    if kind == "rglru":
        y, new_cache = rglru_block(p["rec"], h, cfg, cache=cache)
        x = x + y
        h2 = rmsnorm(x, p["ln2_scale"], cfg.norm_eps)
        h2 = shard(h2, ("batch", "seq", "embed"))   # SP boundary on bf16
        return x + mlp_block(p["mlp"], h2, cfg), new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack init / forward / decode
# ---------------------------------------------------------------------------

def _group_counts(cfg: ArchConfig, n_layers: int) -> Tuple[Tuple[str, ...], int, int]:
    pattern = default_pattern(cfg)
    g = n_layers // len(pattern)
    rem = n_layers % len(pattern)
    return pattern, g, rem


def init_stack(key, cfg: ArchConfig, dtype, *, n_layers: Optional[int] = None,
               encoder: bool = False) -> Dict[str, Any]:
    n_layers = n_layers or cfg.n_layers
    pattern = ("enc_attn_mlp",) if encoder else default_pattern(cfg)
    g = n_layers // len(pattern)
    rem = n_layers % len(pattern)
    keys = jax.random.split(key, len(pattern) + max(rem, 1))
    groups = {}
    for pos, kind in enumerate(pattern):
        sub = jax.random.split(keys[pos], max(g, 1))
        stacked = [init_block(kind, sub[i], cfg, dtype) for i in range(g)]
        groups[f"p{pos}_{kind}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *stacked) if g else {}
    rem_params = [init_block(pattern[i], keys[len(pattern) + i], cfg, dtype)
                  for i in range(rem)]
    return {"groups": groups, "rem": rem_params}


def _stack_meta(cfg: ArchConfig, n_layers: Optional[int], encoder: bool):
    n_layers = n_layers or cfg.n_layers
    pattern = ("enc_attn_mlp",) if encoder else default_pattern(cfg)
    g = n_layers // len(pattern)
    rem = n_layers % len(pattern)
    return pattern, g, rem


def stack_forward(stack_params, x, cfg: ArchConfig, ctx, *,
                  n_layers: Optional[int] = None,
                  encoder: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Train/prefill forward through the whole stack. Returns (x, aux_sum)."""
    pattern, g, rem = _stack_meta(cfg, n_layers, encoder)

    def group_fn(x, slices):
        aux_g = jnp.zeros((), jnp.float32)
        for pos, kind in enumerate(pattern):
            p = slices[f"p{pos}_{kind}"]
            x, _, aux = apply_block(kind, p, x, cfg, ctx)
            aux_g = aux_g + aux
        return x, aux_g

    if cfg.remat:
        if cfg.remat_policy == "save_block_outputs":
            # block outputs are seq-sharded under SP (tiny): saving them skips
            # the recompute-side all-gathers in the backward pass.
            policy = jax.checkpoint_policies.save_only_these_names("block_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        group_fn = jax.checkpoint(group_fn, policy=policy)

    def body(carry, slices):
        x, aux_acc = carry
        x, aux_g = group_fn(x, slices)
        return (x, aux_acc + aux_g), None

    from repro.dist.sharding import unroll_active

    aux0 = jnp.zeros((), jnp.float32)
    if g and unroll_active():
        for i in range(g):
            slices = jax.tree_util.tree_map(lambda a: a[i], stack_params["groups"])
            x, aux_g = group_fn(x, slices)
            aux0 = aux0 + aux_g
    elif g:
        (x, aux0), _ = jax.lax.scan(body, (x, aux0), stack_params["groups"])
    for i in range(rem):
        x, _, aux = apply_block(pattern[i], stack_params["rem"][i], x, cfg, ctx)
        aux0 = aux0 + aux
    return x, aux0


def _init_block_cache(kind, cfg: ArchConfig, batch: int, max_len: int, dtype):
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind in ("attn_mlp", "attn_moe", "enc_attn_mlp"):
        shp = (batch, max_len, KV, hd)
        return {"self": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))}
    if kind == "attn_local":
        w = min(cfg.local_window or max_len, max_len)
        shp = (batch, w, KV, hd)
        return {"self": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))}
    if kind == "attn_cross_mlp":
        shp = (batch, max_len, KV, hd)
        xshp = (batch, cfg.encoder_seq, KV, hd)
        return {"self": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype)),
                "cross": (jnp.zeros(xshp, dtype), jnp.zeros(xshp, dtype))}
    if kind == "mamba":
        return init_mamba_cache(cfg, batch, dtype)
    if kind == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               *, n_layers: Optional[int] = None):
    n_layers = n_layers or cfg.n_layers
    pattern, g, rem = _group_counts(cfg, n_layers)
    groups = {}
    for pos, kind in enumerate(pattern):
        single = _init_block_cache(kind, cfg, batch, max_len, dtype)
        groups[f"p{pos}_{kind}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (g,) + a.shape), single) if g else {}
    rem_caches = [_init_block_cache(pattern[i], cfg, batch, max_len, dtype)
                  for i in range(rem)]
    return {"groups": groups, "rem": rem_caches}


def stack_decode(stack_params, cache, x, cfg: ArchConfig, ctx):
    """One decode step. Returns (x, new_cache)."""
    pattern, g, rem = _stack_meta(cfg, None, False)

    def body(x, slices):
        p_slices, c_slices = slices
        new_c = {}
        for pos, kind in enumerate(pattern):
            key = f"p{pos}_{kind}"
            x, nc, _ = apply_block(kind, p_slices[key], x, cfg, ctx, cache=c_slices[key])
            new_c[key] = nc if nc is not None else c_slices[key]
        return x, new_c

    from repro.dist.sharding import unroll_active

    if g and unroll_active():
        outs = []
        for i in range(g):
            slc = jax.tree_util.tree_map(lambda a: a[i],
                                         (stack_params["groups"], cache["groups"]))
            x, nc = body(x, slc)
            outs.append(nc)
        new_groups = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    elif g:
        x, new_groups = jax.lax.scan(body, x, (stack_params["groups"], cache["groups"]))
    else:
        new_groups = cache["groups"]
    new_rem = []
    for i in range(rem):
        x, nc, _ = apply_block(pattern[i], stack_params["rem"][i], x, cfg, ctx,
                               cache=cache["rem"][i])
        new_rem.append(nc if nc is not None else cache["rem"][i])
    return x, {"groups": new_groups, "rem": new_rem}


# ---------------------------------------------------------------------------
# Full language model (embed -> stack -> norm -> head)
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    params = {
        "embed": {"tokens": dense_init(ks[0], (cfg.vocab_size, d), scale=0.02, dtype=dtype)},
        "layers": init_stack(ks[1], cfg, dtype),
        "final_norm_scale": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (d, cfg.vocab_size), dtype=dtype)
    if cfg.n_prefix_embeds:
        params["patch_proj"] = dense_init(ks[3], (d, d), dtype=dtype)
    if cfg.is_encdec:
        params["encoder"] = init_stack(ks[3], cfg, dtype,
                                       n_layers=cfg.encoder_layers, encoder=True)
        params["enc_norm_scale"] = jnp.zeros((d,), dtype)
    return params


def _embed(params, tokens, cfg, prefix_embeds=None):
    x = jnp.take(params["embed"]["tokens"], tokens, axis=0)
    if cfg.n_prefix_embeds and prefix_embeds is not None:
        proj = prefix_embeds.astype(x.dtype) @ params["patch_proj"]
        n = cfg.n_prefix_embeds
        pos_mask = (jnp.arange(x.shape[1]) < n)[None, :, None]
        pe = jnp.zeros_like(x).at[:, :n, :].set(proj[:, :n, :])
        x = jnp.where(pos_mask, pe, x)
    return shard(x, ("batch", "seq_res", "embed"))


def _head(params, x, cfg):
    x = rmsnorm(x, params["final_norm_scale"], cfg.norm_eps)
    head = params["embed"]["tokens"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard(logits, ("batch", "seq", "vocab"))


def encode(params, frames, cfg: ArchConfig):
    """Whisper-style encoder over precomputed frame embeddings (conv stub)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = shard(x, ("batch", "seq_res", "embed"))
    ctx = {"positions": jnp.arange(x.shape[1])}
    x, _ = stack_forward(params["encoder"], x, cfg, ctx,
                         n_layers=cfg.encoder_layers, encoder=True)
    return rmsnorm(x, params["enc_norm_scale"], cfg.norm_eps)


def lm_forward(params, tokens, cfg: ArchConfig, *, prefix_embeds=None,
               encoder_frames=None):
    """Train/prefill forward. Returns (logits, aux_loss)."""
    x = _embed(params, tokens, cfg, prefix_embeds)
    ctx = {"positions": jnp.arange(tokens.shape[1])}
    if cfg.is_encdec:
        enc = encode(params, encoder_frames, cfg)

        def cross_kv_fn_factory(enc):
            def fn(p_cross):
                B, F, d = enc.shape
                KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
                k = (enc @ p_cross["wk"]).reshape(B, F, KV, hd)
                v = (enc @ p_cross["wv"]).reshape(B, F, KV, hd)
                return k, v
            return fn

        ctx["cross_kv_fn"] = cross_kv_fn_factory(enc)
    x, aux = stack_forward(params["layers"], x, cfg, ctx)
    return _head(params, x, cfg), aux


def lm_decode(params, cache, tokens, cfg: ArchConfig, *, pos: jax.Array):
    """One decode step for the whole batch (aligned streams at position `pos`).

    tokens [B, 1]; pos scalar absolute position. Returns (logits, new_cache).
    """
    x = _embed(params, tokens, cfg)
    # ring-buffer slot for local attention; absolute slot for global
    ctx = {
        "positions": jnp.asarray(pos)[None, None],   # rope position, [1,1]
        "cache_length": None,                        # filled per-kind below
        "cache_slot": None,
        "pos": pos,
    }
    # cache_length/slot depend on kind (ring vs linear); pass both variants and
    # let apply_block pick via ctx. We set linear defaults; attn_local uses ring.
    ctx["cache_length"] = jnp.broadcast_to(pos + 1, (tokens.shape[0],))
    ctx["cache_slot"] = pos
    x, new_cache = stack_decode(params["layers"], cache, x, cfg, ctx)
    return _head(params, x, cfg), new_cache
