"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ u_t)

Train/prefill uses an associative scan (log-space first-order recurrence);
decode is the O(1) elementwise update. The full recurrent block is
conv1d -> RG-LRU on one branch, gated by a GeLU branch (Griffin Fig. 2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.dist.sharding import shard

__all__ = ["init_rglru", "rglru_block", "init_rglru_cache", "rglru_scan"]

_C = 8.0


def init_rglru(key, cfg, dtype) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype=dtype),        # recurrent branch
        "w_gate_branch": dense_init(ks[1], (d, w), dtype=dtype),
        "conv": {"w": dense_init(ks[2], (cfg.conv_width, w), dtype=dtype),
                 "b": jnp.zeros((w,), dtype)},
        "wa": dense_init(ks[3], (w, w), scale=0.02, dtype=dtype),
        "wx": dense_init(ks[4], (w, w), scale=0.02, dtype=dtype),
        "ba": jnp.zeros((w,), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        # Lambda init so a^c is in (0.9, 0.999) at r=1 — Griffin's init range
        "a_param": jnp.full((w,), 0.7, jnp.float32),
        "w_out": dense_init(ks[5], (w, d), scale=1.0 / jnp.sqrt((w) * 2.0 * max(cfg.n_layers, 1)), dtype=dtype),
    }


def rglru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None):
    """First-order recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    a, b: [B, S, W]. Returns h [B, S, W] (h0 folded into the first element).
    """
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def init_rglru_cache(cfg, batch: int, dtype=jnp.float32):
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block(p: dict, x: jax.Array, cfg, *, cache: Optional[dict] = None):
    """Griffin recurrent block. Returns (y [B,S,d], new_cache or None)."""
    from repro.models.ssm import _causal_conv

    B, S, d = x.shape
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    u = x @ p["w_in"]
    u = shard(u, ("batch", "seq", "mlp"))

    if cache is not None and S == 1:
        conv_out, conv_state = _causal_conv(u, p["conv"]["w"], p["conv"]["b"],
                                            state=cache["conv"])
    else:
        conv_out, conv_state = _causal_conv(u, p["conv"]["w"], p["conv"]["b"])
    uc = conv_out.astype(jnp.float32)

    r = jax.nn.sigmoid(uc @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uc @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["a_param"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uc)

    new_cache = None
    if cache is not None and S == 1:
        h = a[:, 0] * cache["h"] + gated_in[:, 0]
        hs = h[:, None, :]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
    else:
        h0 = cache["h"] if cache is not None else None
        hs = rglru_scan(a, gated_in, h0)
        if cache is not None:
            new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                         "h": hs[:, -1, :]}

    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    return shard(y, ("batch", "seq_res", "embed")), new_cache
