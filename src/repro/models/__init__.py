from repro.models.api import ModelBundle, build, cross_entropy

__all__ = ["ModelBundle", "build", "cross_entropy"]
