"""Streaming incremental PARAFAC2 service — "PARAFAC2 as an endpoint".

Every fit elsewhere in the repo is a batch job over a frozen dataset; the
paper's target workload (EHR phenotyping over a growing population) is
append-only: new subjects arrive, existing subjects accrue observations.
This module serves that workload. A :class:`StreamService` warm-starts from
a fitted ``(H, V, W)`` bundle and serves *append* requests with the factor
matrices FIXED — each new/touched subject needs only its own Procrustes
basis ``Q_k`` and its own W row, both independent across subjects, so
requests batch into one padded, jitted dispatch
(:func:`repro.core.parafac2.update_subjects` via
:func:`repro.core.engine.make_subject_update`), modeled on the
``launch/serve.py`` prefill/decode loop:

    request queue -> padded subject batch (pinned geometry,
    ``repro.sparse.bucketing.fixed_plan``) -> ONE compiled dispatch ->
    per-request W rows + residuals + latency stats.

Drift and refits: the service tracks per-subject residuals, so
``stream_fit`` is the EXACT fit of the union dataset at the current factors
(old subjects' residuals are unchanged while H/V are frozen). ``drift`` is
how far that has fallen below the fit at the last full (re)fit; when it
crosses ``drift_threshold`` the service triggers a full refit over the
union through the ordinary engines (``opts.engine`` — host/scan/mesh),
warm-started from the current factors (``refit="warm"``) or from the
deterministic cold init (``refit="cold"``, bitwise-reproducing a batch fit
over the same data). ``checkpoint/ckpt.py`` persists the warm state.

Temporal regularization (tPARAFAC2, PAPERS.md): ``smooth_lam > 0`` anchors
a *touched* subject's streamed W row to its previous row with a quadratic
penalty ``lam * ||w - w_prev||^2`` — folded exactly into the row's normal
equations, so it composes with any configured W constraint.

CLI (driver):

  PYTHONPATH=src python -m repro.launch.stream --dataset synthetic \
      --scale 0.003 --rank 4 --warm-iters 20 --warm-frac 0.6 \
      --batch-slots 8 --drift-threshold 0.05 --smooth 0.1 \
      --format auto --json out.json

``--appends FILE.jsonl`` replays externally supplied append payloads (one
JSON object per line: ``rows``/``cols``/``vals`` [+ ``n_rows``, + optional
``subject`` for accrual onto an existing id]); malformed payloads fail fast
with ``ValueError``. ``--json`` writes the machine-readable latency /
throughput / drift summary CI and the stream benchmark consume. See
docs/ARCHITECTURE.md (stage 9).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import (
    Parafac2Options, bucketize, fit, init_state, update_subjects)
from repro.core.engine import make_subject_update
from repro.core.constraints import (
    available as available_constraints, constraint_summary,
    parse_constraint_arg)
from repro.core.irregular import Bucketed
from repro.launch.summary import resolved_options, run_summary
from repro.sparse import (
    IrregularCOO, SubjectCOO, fixed_plan, plan_buckets, route_formats)
from repro.sparse.bucketing import SCOO_DENSITY_THRESHOLD

__all__ = ["AppendResult", "StreamService", "synthetic_stream",
           "validate_payload", "main"]


def _ceil_to(x: int, align: int) -> int:
    return max(align, ((int(x) + align - 1) // align) * align)


# ---------------------------------------------------------------------------
# append payloads
# ---------------------------------------------------------------------------

def validate_payload(payload: Any, n_cols: int,
                     n_known: int) -> Tuple[Optional[int], SubjectCOO]:
    """Fail-fast validation of one append payload.

    A payload is a mapping with equal-length ``rows``/``cols``/``vals``
    observation triplets (local row ids within the appended block), an
    optional ``n_rows`` (number of observation rows in the block; defaults
    to ``max(rows) + 1``), and an optional ``subject`` id — present means
    the block accrues onto that EXISTING subject, absent means a new
    subject. Returns ``(subject_id_or_None, block_slice)``; raises
    ``ValueError`` naming the first problem found (the service rejects the
    request before it ever reaches the queue or the device).
    """
    if not isinstance(payload, dict):
        raise ValueError(f"append payload must be a mapping, got "
                         f"{type(payload).__name__}")
    for key in ("rows", "cols", "vals"):
        if key not in payload:
            raise ValueError(f"append payload missing required key {key!r}")
    try:
        rows = np.asarray(payload["rows"], dtype=np.int64)
        cols = np.asarray(payload["cols"], dtype=np.int64)
        vals = np.asarray(payload["vals"], dtype=np.float64)
    except (TypeError, ValueError) as e:
        raise ValueError(f"append payload triplets not numeric: {e}") from None
    if not (rows.ndim == cols.ndim == vals.ndim == 1):
        raise ValueError("append payload rows/cols/vals must be 1-D lists")
    if not (rows.size == cols.size == vals.size):
        raise ValueError(
            f"append payload triplet lengths differ: rows={rows.size} "
            f"cols={cols.size} vals={vals.size}")
    if rows.size == 0:
        raise ValueError("append payload has no observations")
    if rows.min() < 0:
        raise ValueError("append payload has negative row indices")
    if cols.min() < 0 or cols.max() >= n_cols:
        raise ValueError(
            f"append payload column ids must be in [0, {n_cols}), got "
            f"[{cols.min()}, {cols.max()}]")
    if not np.all(np.isfinite(vals)):
        raise ValueError("append payload values must be finite")
    n_rows = payload.get("n_rows", int(rows.max()) + 1)
    if not isinstance(n_rows, (int, np.integer)) or n_rows < int(rows.max()) + 1:
        raise ValueError(
            f"append payload n_rows={n_rows!r} inconsistent with max row "
            f"index {int(rows.max())}")
    sid = payload.get("subject")
    if sid is not None:
        if not isinstance(sid, (int, np.integer)):
            raise ValueError(f"append payload subject id must be an int, "
                             f"got {sid!r}")
        if not 0 <= sid < n_known:
            raise ValueError(
                f"append payload subject id {sid} unknown "
                f"(service knows {n_known} subjects)")
    block = SubjectCOO(rows=rows.astype(np.int32), cols=cols.astype(np.int32),
                       vals=vals, n_rows=int(n_rows), n_cols=n_cols)
    return (None if sid is None else int(sid)), block


def _merge_block(base: SubjectCOO, block: SubjectCOO) -> SubjectCOO:
    """Accrue an observation block onto an existing slice: block rows are
    local to the block, appended AFTER the existing observation rows."""
    off = base.n_rows
    return SubjectCOO(
        rows=np.concatenate([base.rows, block.rows + off]).astype(np.int32),
        cols=np.concatenate([base.cols, block.cols]).astype(np.int32),
        vals=np.concatenate([base.vals, block.vals]),
        n_rows=base.n_rows + block.n_rows,
        n_cols=base.n_cols)


@dataclasses.dataclass(frozen=True)
class AppendResult:
    """Per-request serving result (one element of a flushed batch)."""

    request_id: int
    subject_id: int
    is_new: bool
    latency_s: float     # wall time of the batch this request rode in
    batch_size: int      # real requests in that batch (before padding)
    resid: float         # ||X_k - Q_k H S_k V^T||_F^2 at the returned row
    w_row: np.ndarray    # the subject's updated W row [R]


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

class StreamService:
    """Batched incremental PARAFAC2 serving over a warm-started model.

    Build via :meth:`warm_start` (fit the initial population) or
    :meth:`from_checkpoint` (restore a previously saved service state).
    ``submit`` queues validated requests; ``flush`` drains the queue in
    padded ``batch_slots``-sized dispatches; ``append`` is submit+flush for
    one request. Drift-triggered refits happen inside ``flush``.
    """

    def __init__(self, subjects: Sequence[SubjectCOO], n_cols: int,
                 opts: Parafac2Options, H, V, W, *,
                 batch_slots: int = 8,
                 drift_threshold: float = 0.05,
                 refit: str = "warm",
                 refit_iters: int = 50,
                 refit_tol: float = 1e-7,
                 smooth_lam: float = 0.0,
                 inner_iters: int = 2,
                 format: str = "auto",
                 max_buckets: int = 4,
                 row_align: int = 8,
                 col_align: int = 8,
                 nnz_align: int = 32,
                 seed: int = 0):
        if opts.w_layout != "global":
            raise ValueError("StreamService needs w_layout='global' (streamed "
                             "W rows are indexed by global subject id)")
        if refit not in ("warm", "cold"):
            raise ValueError(f"refit must be 'warm' or 'cold', got {refit!r}")
        if format not in ("cc", "scoo", "auto"):
            raise ValueError(f"unknown stream format {format!r}")
        if batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        self.opts = opts
        self.n_cols = int(n_cols)
        self.subjects: List[SubjectCOO] = list(subjects)
        self.H = jnp.asarray(H, opts.dtype)
        self.V = jnp.asarray(V, opts.dtype)
        self.W = np.asarray(W, dtype=np.dtype(jnp.dtype(opts.dtype).name))
        self.batch_slots = int(batch_slots)
        self.drift_threshold = float(drift_threshold)
        self.refit_mode = refit
        self.refit_iters = int(refit_iters)
        self.refit_tol = float(refit_tol)
        self.smooth_lam = float(smooth_lam)
        self.inner_iters = int(inner_iters)
        self.fmt = format
        self.max_buckets = int(max_buckets)
        self.row_align = int(row_align)
        self.col_align = int(col_align)
        self.nnz_align = int(nnz_align)
        self.seed = int(seed)

        # per-subject residual/norm bookkeeping: stream_fit stays the exact
        # union fit because H/V are frozen between refits
        self._sub_norm = np.asarray(
            [float(np.sum(np.square(s.vals, dtype=np.float64)))
             for s in self.subjects], dtype=np.float64)
        self._sub_resid = np.zeros(len(self.subjects), dtype=np.float64)
        self.baseline_fit = float("nan")

        # sticky padded batch geometry (grows monotonically; each distinct
        # (geometry, format) is one compiled dispatch)
        self._i_pad = self.row_align
        self._c_pad = self.col_align
        self._n_pad = self.nnz_align
        self._geometries: set = set()

        self._update = make_subject_update(
            opts, smooth_lam=self.smooth_lam, inner_iters=self.inner_iters)

        self._queue: List[Tuple[int, Optional[int], SubjectCOO]] = []
        self._next_request = 0
        self.latencies: List[float] = []
        self.batch_latencies: List[float] = []
        self.n_appends = 0
        self.n_batches = 0
        self.n_new = 0
        self.n_touched = 0
        self.refit_at: List[int] = []
        self.drift_max = 0.0

    # -- constructors --------------------------------------------------------

    @classmethod
    def warm_start(cls, data: IrregularCOO, opts: Parafac2Options, *,
                   iters: int = 50, tol: float = 1e-7, seed: int = 0,
                   verbose: bool = False, **kw) -> Tuple["StreamService", dict]:
        """Fit the initial population in batch, then serve appends on top.

        Returns ``(service, warm_info)`` with the warm fit/iteration stats.
        """
        svc = cls(data.subjects, data.n_cols, opts,
                  H=jnp.eye(opts.rank, dtype=opts.dtype),
                  V=jnp.zeros((data.n_cols, opts.rank), opts.dtype),
                  W=np.ones((data.n_subjects, opts.rank)), seed=seed, **kw)
        t0 = time.perf_counter()
        bt = svc._bucketize_union(svc.union_data())
        state, hist = fit(bt, opts, max_iters=iters, tol=tol, seed=seed,
                          verbose=verbose)
        svc._adopt(bt, state.H, state.V, state.W)
        info = {"fit": float(hist[-1]), "iters": len(hist),
                "seconds": time.perf_counter() - t0,
                "n_subjects": data.n_subjects, "baseline_fit": svc.baseline_fit}
        return svc, info

    @classmethod
    def from_checkpoint(cls, directory: str, data: IrregularCOO,
                        opts: Parafac2Options, **kw) -> "StreamService":
        """Restore a saved service state (H/V/W + residual bookkeeping) over
        the matching union dataset — the elastic-resume path for a service
        process that died mid-stream."""
        svc = cls(data.subjects, data.n_cols, opts,
                  H=jnp.eye(opts.rank, dtype=opts.dtype),
                  V=jnp.zeros((data.n_cols, opts.rank), opts.dtype),
                  W=np.ones((data.n_subjects, opts.rank)), **kw)
        template = {"H": svc.H, "V": svc.V, "W": jnp.asarray(svc.W),
                    "sub_norm": jnp.asarray(svc._sub_norm),
                    "sub_resid": jnp.asarray(svc._sub_resid)}
        tree, _, extra = ckpt.restore(directory, template)
        if int(extra.get("n_subjects", data.n_subjects)) != data.n_subjects:
            raise ValueError(
                f"checkpoint was written with {extra.get('n_subjects')} "
                f"subjects but the supplied union dataset has "
                f"{data.n_subjects}")
        svc.H = tree["H"]
        svc.V = tree["V"]
        svc.W = np.array(tree["W"])
        svc._sub_norm = np.array(tree["sub_norm"], dtype=np.float64)
        svc._sub_resid = np.array(tree["sub_resid"], dtype=np.float64)
        svc.baseline_fit = float(extra.get("baseline_fit", float("nan")))
        svc.n_appends = int(extra.get("n_appends", 0))
        svc._i_pad = int(extra.get("i_pad", svc._i_pad))
        svc._c_pad = int(extra.get("c_pad", svc._c_pad))
        svc._n_pad = int(extra.get("n_pad", svc._n_pad))
        return svc

    def save(self, directory: str) -> str:
        """Persist the warm state through ``checkpoint/ckpt.py`` (atomic,
        step-stamped by append count, elastic-restorable)."""
        tree = {"H": self.H, "V": self.V, "W": jnp.asarray(self.W),
                "sub_norm": jnp.asarray(self._sub_norm),
                "sub_resid": jnp.asarray(self._sub_resid)}
        return ckpt.save(directory, self.n_appends, tree, extra={
            "baseline_fit": self.baseline_fit,
            "n_subjects": len(self.subjects),
            "n_appends": self.n_appends,
            # sticky batch geometry: restoring it makes the resumed service
            # dispatch bit-identical batches to the uninterrupted one
            "i_pad": self._i_pad, "c_pad": self._c_pad, "n_pad": self._n_pad,
        })

    # -- model/fit bookkeeping ----------------------------------------------

    def union_data(self) -> IrregularCOO:
        """The accumulated dataset: warm subjects + every streamed append."""
        return IrregularCOO(subjects=list(self.subjects), n_cols=self.n_cols)

    def _bucketize_union(self, data: IrregularCOO) -> Bucketed:
        """The batch-path bucketization used for warm fits and refits —
        identical to what ``launch/decompose.py`` would build for the same
        data/format, which is what makes the cold-refit parity exact."""
        rc, ccnt, nnzc = data.row_counts(), data.col_counts(), data.nnz_counts()
        plan = plan_buckets(rc, ccnt, max_buckets=self.max_buckets,
                            nnz_counts=nnzc,
                            sort_by="nnz" if self.fmt == "scoo" else "area")
        fmts = route_formats(plan, nnzc, format=self.fmt)
        return bucketize(data, dtype=self.opts.dtype, plan=plan, formats=fmts)

    def _adopt(self, bt: Bucketed, H, V, W) -> None:
        """Install new factors and rebuild the per-subject residual ledger:
        one ``update_subjects`` pass over the full union re-solves every
        subject's (Q_k, w_k) at the new factors, so the stored W rows and
        the residual ledger are exactly consistent."""
        self.H = jnp.asarray(H, self.opts.dtype)
        self.V = jnp.asarray(V, self.opts.dtype)
        W_new, resid = update_subjects(
            bt, self.H, self.V, self.opts, w_init=jnp.asarray(W),
            inner_iters=1)
        self.W = np.array(W_new)  # writable host copy (rows mutate per append)
        self._sub_resid = np.maximum(
            np.asarray(resid, dtype=np.float64), 0.0)
        self.baseline_fit = self.stream_fit

    @property
    def stream_fit(self) -> float:
        """Exact fit of the union dataset at the current factors (each
        subject evaluated at its last-solved ``(Q_k, w_k)``)."""
        total = float(self._sub_norm.sum())
        if total <= 0.0:
            return 1.0
        resid = max(float(self._sub_resid.sum()), 0.0)
        return 1.0 - float(np.sqrt(resid / total))

    @property
    def drift(self) -> float:
        """How far the streamed model has fallen below the last (re)fit."""
        return max(0.0, self.baseline_fit - self.stream_fit)

    def refit(self, *, mode: Optional[str] = None) -> dict:
        """Full ALS refit over the union dataset through ``opts.engine``.

        ``mode="warm"`` starts from the current ``(H, V, W)``;
        ``mode="cold"`` from the deterministic seeded init — bitwise the
        same trajectory a batch ``fit`` over the same data would take.
        """
        mode = self.refit_mode if mode is None else mode
        t0 = time.perf_counter()
        bt = self._bucketize_union(self.union_data())
        state0 = None
        if mode == "warm":
            state0 = init_state(bt, self.opts, self.seed)._replace(
                H=jnp.asarray(self.H, self.opts.dtype),
                V=jnp.asarray(self.V, self.opts.dtype),
                W=jnp.asarray(self.W, self.opts.dtype))
        state, hist = fit(bt, self.opts, max_iters=self.refit_iters,
                          tol=self.refit_tol, seed=self.seed, state=state0)
        self._adopt(bt, state.H, state.V, state.W)
        self.refit_at.append(self.n_appends)
        return {"mode": mode, "iters": len(hist), "fit": float(hist[-1]),
                "baseline_fit": self.baseline_fit,
                "seconds": time.perf_counter() - t0,
                "n_subjects": len(self.subjects)}

    # -- the serving loop ----------------------------------------------------

    def submit(self, payload: dict) -> int:
        """Validate (fail fast) and queue one append request; returns its
        request id. Nothing reaches the device until ``flush``."""
        sid, block = validate_payload(payload, self.n_cols, len(self.subjects))
        rid = self._next_request
        self._next_request += 1
        self._queue.append((rid, sid, block))
        return rid

    def append(self, payload: dict) -> AppendResult:
        """submit + flush for a single request (the one-at-a-time API)."""
        self.submit(payload)
        return self.flush()[-1]

    def flush(self) -> List[AppendResult]:
        """Drain the queue in ``batch_slots``-sized padded dispatches; runs
        the drift check (and any triggered refit) after each batch."""
        results: List[AppendResult] = []
        while self._queue:
            chunk, self._queue = (self._queue[: self.batch_slots],
                                  self._queue[self.batch_slots:])
            results.extend(self._dispatch(chunk))
            self.drift_max = max(self.drift_max, self.drift)
            if self.drift > self.drift_threshold:
                self.refit()
        return results

    def _batch_geometry(self, slices: Sequence[SubjectCOO]) -> Tuple[int, int, int]:
        """Grow the sticky padded geometry to cover this batch."""
        need_i = max(s.n_rows for s in slices)
        need_c = max(s.nonzero_cols().size for s in slices)
        need_n = max(max(s.nnz, 1) for s in slices)
        self._i_pad = max(self._i_pad, _ceil_to(need_i, self.row_align))
        self._c_pad = max(self._c_pad, _ceil_to(need_c, self.col_align))
        self._n_pad = max(self._n_pad, _ceil_to(need_n, self.nnz_align))
        return self._i_pad, self._c_pad, self._n_pad

    def _batch_format(self, slices: Sequence[SubjectCOO],
                      i_pad: int, c_pad: int) -> str:
        if self.fmt in ("cc", "scoo"):
            return self.fmt
        dens = sum(s.nnz for s in slices) / max(
            len(slices) * i_pad * c_pad, 1)
        return "scoo" if dens < SCOO_DENSITY_THRESHOLD else "cc"

    def _dispatch(self, chunk: Sequence[Tuple[int, Optional[int], SubjectCOO]]
                  ) -> List[AppendResult]:
        """One padded batch: stage -> compiled update -> host state commit."""
        t0 = time.perf_counter()
        R = self.opts.rank
        merged: List[SubjectCOO] = []
        metas: List[Tuple[int, Optional[int], bool]] = []
        for rid, sid, block in chunk:
            if sid is None:
                merged.append(block)
                metas.append((rid, None, True))
            else:
                merged.append(_merge_block(self.subjects[sid], block))
                metas.append((rid, sid, False))

        i_pad, c_pad, n_pad = self._batch_geometry(merged)
        fmt = self._batch_format(merged, i_pad, c_pad)
        # subject_align pads every chunk to a multiple of batch_slots, so a
        # short final chunk still reuses the full-batch compiled dispatch
        self._geometries.add((i_pad, c_pad, n_pad, fmt,
                              _ceil_to(len(merged), self.batch_slots)))
        plan = fixed_plan(len(merged), i_pad, c_pad,
                          nnz_pad=n_pad if fmt == "scoo" else None)
        batch = bucketize(
            IrregularCOO(subjects=merged, n_cols=self.n_cols), plan=plan,
            formats=[fmt], subject_align=self.batch_slots,
            dtype=self.opts.dtype)
        # pin the Bucketed aux metadata so every flush shares one jit entry
        batch = Bucketed(buckets=batch.buckets, n_subjects=self.batch_slots,
                         n_cols=self.n_cols, norm_sq=0.0)

        np_dt = np.dtype(jnp.dtype(self.opts.dtype).name)
        w_init = np.ones((self.batch_slots, R), np_dt)
        w_prev = np.zeros((self.batch_slots, R), np_dt)
        pmask = np.zeros((self.batch_slots,), np_dt)
        for slot, (_, sid, is_new) in enumerate(metas):
            if not is_new:
                w_init[slot] = self.W[sid]
                w_prev[slot] = self.W[sid]
                pmask[slot] = 1.0
        W_rows, resid = self._update(
            batch, self.H, self.V, jnp.asarray(w_init), jnp.asarray(w_prev),
            jnp.asarray(pmask))
        W_rows = np.asarray(jax.block_until_ready(W_rows))
        resid = np.asarray(resid)
        latency = time.perf_counter() - t0

        # commit host state per request
        out: List[AppendResult] = []
        for slot, ((_, sid, is_new), slice_) in enumerate(zip(metas, merged)):
            rid = metas[slot][0]
            norm = float(np.sum(np.square(slice_.vals, dtype=np.float64)))
            r = max(float(resid[slot]), 0.0)
            if is_new:
                sid = len(self.subjects)
                self.subjects.append(slice_)
                self.W = np.vstack([self.W, W_rows[slot][None]])
                self._sub_norm = np.append(self._sub_norm, norm)
                self._sub_resid = np.append(self._sub_resid, r)
                self.n_new += 1
            else:
                self.subjects[sid] = slice_
                self.W[sid] = W_rows[slot]
                self._sub_norm[sid] = norm
                self._sub_resid[sid] = r
                self.n_touched += 1
            self.n_appends += 1
            self.latencies.append(latency)
            out.append(AppendResult(
                request_id=rid, subject_id=sid, is_new=is_new,
                latency_s=latency, batch_size=len(chunk), resid=r,
                w_row=W_rows[slot].copy()))
        self.batch_latencies.append(latency)
        self.n_batches += 1
        return out

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        """Machine-readable serving stats (the ``--json`` payload core)."""
        lat = np.asarray(self.latencies, dtype=np.float64)
        lat_ms: Dict[str, float] = {}
        if lat.size:
            lat_ms = {"p50": float(np.percentile(lat, 50) * 1e3),
                      "p99": float(np.percentile(lat, 99) * 1e3),
                      "mean": float(lat.mean() * 1e3),
                      "max": float(lat.max() * 1e3)}
        # every request's latency is its batch's wall time, so throughput
        # divides by the sum over BATCHES, not over requests
        busy = float(np.sum(self.batch_latencies))
        subjects_per_s = (self.n_appends / busy) if busy > 0 else 0.0
        return {
            "appends": self.n_appends, "batches": self.n_batches,
            "new": self.n_new, "touched": self.n_touched,
            "batch_slots": self.batch_slots,
            "latency_ms": lat_ms,
            "subjects_per_s": subjects_per_s,
            "stream_fit": self.stream_fit,
            "baseline_fit": self.baseline_fit,
            "drift": self.drift, "drift_max": self.drift_max,
            "drift_threshold": self.drift_threshold,
            "refits": len(self.refit_at), "refit_at": list(self.refit_at),
            "compiled_geometries": len(self._geometries),
            "n_subjects": len(self.subjects),
            "format": self.fmt, "smooth_lam": self.smooth_lam,
            "inner_iters": self.inner_iters,
        }


# ---------------------------------------------------------------------------
# synthetic stream construction (drivers, tests, benchmarks)
# ---------------------------------------------------------------------------

def synthetic_stream(data: IrregularCOO, *, warm_frac: float = 0.6,
                     touch_frac: float = 0.2, holdout_frac: float = 0.4,
                     seed: int = 0) -> Tuple[IrregularCOO, List[dict]]:
    """Split a dataset into a warm population + an append stream.

    The first ``warm_frac`` of subjects form the warm-start population; the
    rest arrive as *new-subject* payloads. A ``touch_frac`` share of warm
    subjects additionally hold out their last ``holdout_frac`` observation
    rows, which arrive later as *accrual* payloads onto the existing id —
    so the union of warm data + replayed payloads is EXACTLY the original
    dataset (the parity tests rely on this).
    """
    K = data.n_subjects
    n_warm = min(K, max(1, int(round(K * warm_frac))))
    rng = np.random.default_rng(seed)
    warm: List[SubjectCOO] = []
    payloads: List[dict] = []
    for i, s in enumerate(data.subjects[:n_warm]):
        split = max(1, int(round(s.n_rows * (1.0 - holdout_frac))))
        held = s.rows >= split
        if (s.n_rows >= 4 and rng.random() < touch_frac
                and held.any() and (~held).any()):
            warm.append(SubjectCOO(
                rows=s.rows[~held], cols=s.cols[~held], vals=s.vals[~held],
                n_rows=split, n_cols=s.n_cols))
            payloads.append({
                "subject": i,
                "rows": (s.rows[held] - split).tolist(),
                "cols": s.cols[held].tolist(),
                "vals": s.vals[held].tolist(),
                "n_rows": s.n_rows - split,
            })
        else:
            warm.append(s)
    for s in data.subjects[n_warm:]:
        payloads.append({"rows": s.rows.tolist(), "cols": s.cols.tolist(),
                         "vals": s.vals.tolist(), "n_rows": s.n_rows})
    order = rng.permutation(len(payloads))
    return (IrregularCOO(subjects=warm, n_cols=data.n_cols),
            [payloads[i] for i in order])


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def main(argv=None) -> dict:
    from repro.launch.decompose import load_dataset

    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    choices=["choa", "movielens", "synthetic"])
    ap.add_argument("--scale", type=float, default=0.003)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--warm-iters", type=int, default=20,
                    help="batch ALS iterations for the warm-start fit")
    ap.add_argument("--tol", type=float, default=1e-7)
    ap.add_argument("--warm-frac", type=float, default=0.6,
                    help="fraction of subjects in the warm population")
    ap.add_argument("--touch-frac", type=float, default=0.2,
                    help="fraction of warm subjects that later accrue "
                         "held-out observations")
    ap.add_argument("--appends", default="", metavar="FILE.jsonl",
                    help="replay append payloads from this JSONL file "
                         "instead of the synthetic stream (fail-fast on "
                         "malformed payloads)")
    ap.add_argument("--limit", type=int, default=0,
                    help="stream at most this many appends (0 = all)")
    ap.add_argument("--batch-slots", type=int, default=8,
                    help="requests per padded dispatch (the serving batch)")
    ap.add_argument("--drift-threshold", type=float, default=0.05,
                    help="fit drift that triggers a full refit")
    ap.add_argument("--refit", default="warm", choices=["warm", "cold"],
                    help="refit start: warm (current factors) or cold "
                         "(seeded init — bitwise equals a batch fit)")
    ap.add_argument("--refit-iters", type=int, default=50)
    ap.add_argument("--smooth", type=float, default=0.0, metavar="LAM",
                    help="tPARAFAC2 temporal anchor on touched subjects' "
                         "streamed W rows: lam * ||w - w_prev||^2")
    ap.add_argument("--inner-iters", type=int, default=2,
                    help="Q <-> w alternations per streamed subject")
    ap.add_argument("--constraint", default="", metavar="SPECS",
                    help="per-mode factor constraints (as in decompose.py); "
                         f"registered: {', '.join(available_constraints())}")
    ap.add_argument("--backend", default="auto",
                    choices=["jnp", "pallas", "scoo", "auto"])
    ap.add_argument("--format", default="auto", choices=["cc", "scoo", "auto"])
    ap.add_argument("--engine", default="host", choices=["host", "scan", "mesh"],
                    help="engine for the warm fit and refits")
    ap.add_argument("--check-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="",
                    help="save the final service state here (ckpt.py layout)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable latency/throughput/"
                         "drift summary to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.constraint:
        specs = parse_constraint_arg(args.constraint)
    else:
        specs = {"v": "nonneg", "w": "nonneg"}
    opts = Parafac2Options(rank=args.rank, constraints=specs,
                           backend=args.backend, engine=args.engine,
                           check_every=args.check_every)

    data = load_dataset(args.dataset, args.scale, args.seed)
    warm, payloads = synthetic_stream(
        data, warm_frac=args.warm_frac, touch_frac=args.touch_frac,
        seed=args.seed)
    if args.appends:
        with open(args.appends) as f:
            payloads = []
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    payloads.append(json.loads(line))
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{args.appends}:{ln}: not valid JSON: {e}") from None
    if args.limit:
        payloads = payloads[: args.limit]

    print(f"[stream] warm population K={warm.n_subjects} J={warm.n_cols} "
          f"nnz={warm.nnz}; {len(payloads)} appends queued")
    print(f"[constraints] {constraint_summary(specs)}")
    svc, warm_info = StreamService.warm_start(
        warm, opts, iters=args.warm_iters, tol=args.tol, seed=args.seed,
        batch_slots=args.batch_slots, drift_threshold=args.drift_threshold,
        refit=args.refit, refit_iters=args.refit_iters,
        smooth_lam=args.smooth, inner_iters=args.inner_iters,
        format=args.format)
    print(f"[warm] fit={warm_info['fit']:.4f} in {warm_info['iters']} iters "
          f"({warm_info['seconds']:.1f}s)")

    t0 = time.perf_counter()
    for payload in payloads:
        svc.submit(payload)   # fail-fast validation happens HERE
        if len(svc._queue) >= args.batch_slots:
            svc.flush()
    svc.flush()
    stream_s = time.perf_counter() - t0

    st = svc.stats()
    st["subjects_per_s_wall"] = (st["appends"] / stream_s
                                 if stream_s > 0 else 0.0)
    if st["latency_ms"]:
        print(f"[stream] {st['appends']} appends in {st['batches']} batches "
              f"({stream_s:.2f}s wall): p50={st['latency_ms']['p50']:.1f}ms "
              f"p99={st['latency_ms']['p99']:.1f}ms "
              f"{st['subjects_per_s_wall']:.1f} subjects/s")
    print(f"[drift] stream_fit={st['stream_fit']:.4f} "
          f"baseline={st['baseline_fit']:.4f} drift={st['drift']:.4f} "
          f"(max {st['drift_max']:.4f}, threshold {st['drift_threshold']}) "
          f"refits={st['refits']} at {st['refit_at']}")
    if args.ckpt_dir:
        path = svc.save(args.ckpt_dir)
        print(f"[ckpt] saved service state to {path}")

    summary = run_summary(
        "stream",
        # the canonicalized option block every driver shares
        resolved_options(opts, format=args.format, tol=args.tol,
                         seed=args.seed, warm_frac=args.warm_frac,
                         batch_slots=args.batch_slots,
                         drift_threshold=args.drift_threshold,
                         refit=args.refit, smooth_lam=args.smooth),
        dataset=args.dataset, scale=args.scale, rank=args.rank,
        engine=args.engine, backend=args.backend,
        constraints=constraint_summary(specs),
        warm=warm_info,
        stream_seconds=stream_s,
        platform=jax.default_backend(),
        **st,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"[json] wrote {args.json}")
    return summary


if __name__ == "__main__":
    main()
