"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduce \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build


def sample_token(logits: jax.Array, rng: jax.Array, *, temperature: float = 0.0,
                 top_k: int = 0) -> jax.Array:
    """Greedy (temperature 0) or temperature/top-k sampling. logits [B,1,V]."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    x = logits[:, -1, :].astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(x, axis=-1)[:, -top_k][:, None]
        x = jnp.where(x < kth, -jnp.inf, x)
    tok = jax.random.categorical(rng, x, axis=-1)
    return tok[:, None].astype(jnp.int32)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    bundle = build(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = bundle.init_params(rng)

    B, P, G = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab_size)
    max_len = P + G
    cache = bundle.init_cache(B, max_len)
    decode = jax.jit(bundle.decode_step)

    # prefill by teacher-forcing the prompt through the decode path (fills
    # the cache position by position; a production server would use a fused
    # prefill kernel — measured separately by the prefill_32k cells)
    t0 = time.perf_counter()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t : t + 1], jnp.asarray(t))
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    sample_rng = jax.random.PRNGKey(args.seed + 1)
    tok = sample_token(logits, sample_rng, temperature=args.temperature,
                       top_k=args.top_k)
    t0 = time.perf_counter()
    for g in range(G):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok, jnp.asarray(P + g))
        sample_rng, sub = jax.random.split(sample_rng)
        tok = sample_token(logits, sub, temperature=args.temperature,
                           top_k=args.top_k)
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    tput = B * G / decode_s
    print(f"[serve] batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill {prefill_s*1e3:.1f} ms; decode {decode_s*1e3:.1f} ms "
          f"({tput:.1f} tok/s)")
    print(f"[serve] sample continuation: {gen[0, :8].tolist()}")
    return {"tokens_per_s": tput, "generated": gen}


if __name__ == "__main__":
    main()
