"""End-to-end LM training driver.

Runs a real training loop (reduced configs on CPU; full configs on a pod) with
checkpoint/restart, fault injection, straggler watchdog, and the counter-based
data pipeline. Example (the (b) deliverable end-to-end run):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduce \
      --steps 300 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --resume auto
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, reduced
from repro.data import TokenStream
from repro.dist.fault import FaultInjector, StepWatchdog, TransientFault, run_with_retries
from repro.models import build


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduce", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="", choices=["", "auto"])
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject transient faults at these steps (FT test)")
    ap.add_argument("--fail-persistent", action="store_true",
                    help="make injected faults persist past retries, forcing "
                         "the checkpoint-restore + rewind path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    bundle = build(cfg, lr=args.lr, total_steps=args.steps)

    rng = jax.random.PRNGKey(args.seed)
    params = bundle.init_params(rng)
    opt = bundle.init_opt(params)
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq, seed=args.seed)
    start = 0
    if args.resume == "auto" and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt), start, extra = ckpt.restore(args.ckpt_dir, (params, opt))
        stream.restore(extra["data"])
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(bundle.train_step)
    injector = FaultInjector(fail_steps=tuple(args.fail_at),
                             times=4 if args.fail_persistent else 1)
    watchdog = StepWatchdog()
    losses = []

    def one_step(params, opt, step):
        injector.check(step)
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        if cfg.is_encdec:
            batch["encoder_frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.encoder_seq, cfg.d_model))
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.n_prefix_embeds, cfg.d_model))
        return step_fn(params, opt, batch, step)

    step = start
    while step < args.steps:
        t0 = time.perf_counter()
        try:
            params, opt, metrics = run_with_retries(
                one_step, params, opt, step,
                on_retry=lambda a, e: print(f"[fault] step {step}: {e}; retry {a + 1}"))
        except TransientFault:
            # persistent failure path: restore newest checkpoint and REWIND —
            # the steps between the checkpoint and the fault re-run against
            # the restored state (a for-loop would silently skip them).
            if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
                (params, opt), step0, extra = ckpt.restore(args.ckpt_dir, (params, opt))
                stream.restore(extra["data"])
                del losses[max(step0 - start, 0):]
                step = step0
                print(f"[fault] restored from checkpoint at step {step0}")
                continue
            raise
        dt = time.perf_counter() - t0
        if watchdog.observe(step, dt):
            print(f"[straggler] step {step} took {dt:.2f}s (>{watchdog.factor}x median)")
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  ({dt*1e3:.0f} ms)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            stream.step = step + 1
            path = ckpt.save(args.ckpt_dir, step + 1, (params, opt),
                             extra={"data": stream.state()})
            print(f"[ckpt] wrote {path}")
        step += 1

    if losses:
        print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    else:
        print(f"[train] done: nothing to do (resumed at step {start} of {args.steps})")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "flagged_stragglers": watchdog.flagged}


if __name__ == "__main__":
    main()
