"""One machine-readable summary schema for every launch driver.

``decompose.py``, ``stream.py`` and ``dryrun.py`` used to hand-roll three
different ``--json`` dicts; CI and the benchmark gate had to know each one.
Every driver summary now goes through :func:`run_summary`, which stamps

* ``schema_version`` — bumped whenever a consumer-visible key changes
  meaning (adding keys is compatible and does not bump it);
* ``kind`` — which driver produced the blob (``decompose`` | ``stream`` |
  ``dryrun``);
* ``resolved_options`` — the CANONICALIZED option block
  (:func:`resolved_options`): rank/engine/backend/dtype plus the resolved
  constraint specs and compress spec, so a consumer reads what actually ran
  rather than re-deriving defaults from CLI flags.

Driver-specific payload keys stay at the top level (the historical layout
tests and benchmarks consume); the schema block is additive. Notable
decompose additions: ``supervisor`` — the fault-tolerant fit's
:class:`repro.dist.supervisor.SupervisorReport` as a dict
(retry/restore/rollback counts, straggler chunk ids, checkpoints written,
resume step, final ridge) or ``None`` for a bare fit — and
``shard_balance`` — the nnz-balanced shard planner's before/after
max-over-mean imbalance under ``engine="mesh"`` or ``None``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

__all__ = ["SCHEMA_VERSION", "resolved_options", "run_summary"]

# version 2 = the unified schema (1 was the implicit hand-rolled layouts)
SCHEMA_VERSION = 2


def resolved_options(opts=None, **extra) -> Dict[str, Any]:
    """Canonical option block from a ``Parafac2Options`` (+ driver extras).

    Specs are canonicalized through the same parsers ``fit`` uses
    (``repro.core.constraints`` / ``repro.core.compress``), so two spellings
    of one configuration serialize identically. ``extra`` keys (format, tol,
    seed, ...) are driver-level knobs that ride along verbatim.
    """
    block: Dict[str, Any] = {}
    if opts is not None:
        from repro.core.compress import preprocess_summary
        from repro.core.constraints import constraint_summary

        block.update(
            rank=opts.rank,
            engine=opts.engine,
            backend=opts.backend,
            check_every=opts.check_every,
            w_layout=opts.w_layout,
            procrustes=opts.procrustes,
            dtype=np.dtype(opts.dtype).name,
            constraints=constraint_summary(opts.constraint_specs()),
            compress=preprocess_summary(opts.compress, opts.rank),
        )
    block.update(extra)
    return block


def run_summary(kind: str, options: Optional[Dict[str, Any]] = None,
                **payload) -> Dict[str, Any]:
    """Assemble one schema-stamped driver summary.

    ``options`` is a :func:`resolved_options` block; ``payload`` keys land at
    the top level (and must not collide with the schema keys).
    """
    reserved = {"schema_version", "kind", "resolved_options"}
    clash = reserved & set(payload)
    if clash:
        raise ValueError(f"summary payload keys {sorted(clash)} collide with "
                         f"the schema block")
    return {
        "schema_version": SCHEMA_VERSION,
        "kind": kind,
        "resolved_options": dict(options or {}),
        **payload,
    }
