import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: the dry-run (and only the dry-run) builds the
# 512-chip production mesh out of host placeholder devices.

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.roofline import (
    TPU_V5E, calibrate_flops_convention, model_flops, roofline_terms)
from repro.configs import SHAPES, applicable_shapes, get_config, list_archs
from repro.core import (
    Bucket, Bucketed, Parafac2Options, Parafac2State, SparseBucket, als_step)
from repro.dist.sharding import LM_RULES, SP_RULES, axis_rules, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import build

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "results", "dryrun.json")


# ---------------------------------------------------------------------------
# sharding builders
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, names) -> int:
    n = 1
    for nm in names:
        if nm in mesh.axis_names:
            n *= mesh.devices.shape[mesh.axis_names.index(nm)]
    return n


def _div(n: int, mesh: Mesh, names) -> bool:
    s = _axis_size(mesh, names)
    return s > 1 and n % s == 0


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shardings(specs: Dict[str, Any], mesh: Mesh):
    dp = _dp_axes(mesh)

    def spec_for(leaf):
        if not hasattr(leaf, "shape") or not leaf.shape:
            return NamedSharding(mesh, P())
        B = leaf.shape[0]
        if _div(B, mesh, dp):
            return NamedSharding(mesh, P(dp + (), *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec_for, specs)


def cache_shardings(cache_shapes, mesh: Mesh):
    dp = _dp_axes(mesh)

    def visit(path, leaf):
        pathstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        shape = leaf.shape
        nd = len(shape)
        parts = [None] * nd
        def set_dim(d, axes):
            parts[d] = axes if len(axes) > 1 else axes[0]
        if "self" in pathstr or "cross" in pathstr:
            # kv cache [...,B,S,KV,hd]
            b_dim, s_dim, kv_dim = nd - 4, nd - 3, nd - 2
            if _div(shape[b_dim], mesh, dp):
                set_dim(b_dim, dp)
            if _div(shape[kv_dim], mesh, ("model",)):
                set_dim(kv_dim, ("model",))
            elif _div(shape[s_dim], mesh, ("model",)):
                set_dim(s_dim, ("model",))
        elif "ssm" in pathstr:
            # [..., B, H, P, N]
            b_dim, h_dim = nd - 4, nd - 3
            if _div(shape[b_dim], mesh, dp):
                set_dim(b_dim, dp)
            if _div(shape[h_dim], mesh, ("model",)):
                set_dim(h_dim, ("model",))
        elif "conv" in pathstr:
            b_dim, c_dim = nd - 3, nd - 1
            if _div(shape[b_dim], mesh, dp):
                set_dim(b_dim, dp)
            if _div(shape[c_dim], mesh, ("model",)):
                set_dim(c_dim, ("model",))
        elif pathstr.endswith("h") or "/h" in pathstr:
            b_dim, w_dim = nd - 2, nd - 1
            if _div(shape[b_dim], mesh, dp):
                set_dim(b_dim, dp)
            if _div(shape[w_dim], mesh, ("model",)):
                set_dim(w_dim, ("model",))
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


# ---------------------------------------------------------------------------
# one LM cell
# ---------------------------------------------------------------------------

def _lower_compile(cfg, shape_name: str, mesh: Mesh, *, unroll: bool, rules=LM_RULES,
                   microbatches: int = 1):
    """Lower + compile one step function for `cfg` on `mesh`."""
    from repro.dist.sharding import unroll_loops
    import contextlib

    shape = SHAPES[shape_name]
    bundle = build(cfg, microbatches=microbatches)
    ctxs = [axis_rules(rules, mesh), mesh]
    if unroll:
        ctxs.append(unroll_loops())
    with contextlib.ExitStack() as stack:
        for c in ctxs:
            stack.enter_context(c)
        rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        params_shapes = jax.eval_shape(bundle.init_params, rng_spec)
        p_sh = param_shardings(params_shapes, mesh)
        specs = bundle.input_specs(shape_name)
        t0 = time.perf_counter()
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(bundle.init_opt, params_shapes)
            o_sh = param_shardings(opt_shapes, mesh)
            b_sh = batch_shardings(specs["batch"], mesh)
            lowered = jax.jit(
                bundle.train_step,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
            ).lower(params_shapes, opt_shapes, specs["batch"], specs["step"])
        elif shape.kind == "prefill":
            b_sh = batch_shardings(specs["batch"], mesh)
            lowered = jax.jit(
                bundle.prefill_step, in_shardings=(p_sh, b_sh),
            ).lower(params_shapes, specs["batch"])
        else:  # decode
            c_sh = cache_shardings(specs["cache"], mesh)
            t_sh = batch_shardings({"t": specs["tokens"]}, mesh)["t"]
            lowered = jax.jit(
                bundle.decode_step,
                in_shardings=(p_sh, c_sh, t_sh, None),
                out_shardings=(None, c_sh),
            ).lower(params_shapes, specs["cache"], specs["tokens"], specs["pos"])
        lower_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
    return compiled, lower_s, compile_s


def _variant_cfg(cfg, units: int):
    """Scale every stacked depth to `units` pattern-groups (affine-cost probe)."""
    import dataclasses as dc
    from repro.models.transformer import default_pattern

    p = len(default_pattern(cfg))
    kw = {"n_layers": units * p, "remat": cfg.remat}
    if cfg.is_encdec:
        kw["encoder_layers"] = units
    return dc.replace(cfg, **kw)


def _raw_costs(compiled, hw) -> Dict[str, float]:
    t = roofline_terms(compiled, hw=hw)
    return {"hlo_flops": t["hlo_flops"], "hlo_bytes": t["hlo_bytes"],
            "collective_bytes": t["collective_bytes"]}


def run_cell(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
             hw=TPU_V5E, *, roofline: bool = True, sp: bool = False,
             remat_policy: str = "", microbatches: int = 1) -> Dict[str, Any]:
    """One (arch x shape x mesh) cell.

    Full scanned model: compiled for the shardability proof + memory_analysis.
    Roofline terms: XLA cost analysis counts while-loop bodies once, so the
    three terms come from TWO fully-unrolled probe models (1 and 2 pattern-
    groups deep) extrapolated affinely in depth — exact because step cost is
    affine in layer count (intercept = embed/head/loss/optimizer).
    """
    cfg = get_config(arch)
    if remat_policy:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    rules = SP_RULES if sp else LM_RULES
    n_chips = int(np.prod(mesh.devices.shape))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "sp": sp,
        "kind": shape.kind, "n_chips": n_chips,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    rec["microbatches"] = microbatches
    compiled, rec["lower_s"], rec["compile_s"] = _lower_compile(
        cfg, shape_name, mesh, unroll=False, rules=rules, microbatches=microbatches)
    mem = compiled.memory_analysis()
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        rec[attr] = int(getattr(mem, attr, 0) or 0)
    rec["bytes_per_device"] = (
        rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"])
    rec["fits_hbm_16g"] = rec["bytes_per_device"] <= 16 * 2**30

    if not roofline:
        return rec

    # --- unrolled 1-/2-group probes -> affine extrapolation in depth --------
    from repro.models.transformer import default_pattern

    p = len(default_pattern(cfg))
    units_full = cfg.n_layers / p
    c1, _, s1 = _lower_compile(_variant_cfg(cfg, 1), shape_name, mesh, unroll=True, rules=rules,
                               microbatches=microbatches)
    c2, _, s2 = _lower_compile(_variant_cfg(cfg, 2), shape_name, mesh, unroll=True, rules=rules,
                               microbatches=microbatches)
    rec["probe_compile_s"] = s1 + s2
    r1, r2 = _raw_costs(c1, hw), _raw_costs(c2, hw)
    extrap = {}
    for k in r1:
        per_unit = r2[k] - r1[k]
        extrap[k] = max(r1[k] + (units_full - 1.0) * per_unit, 0.0)
        # the microbatch accumulation scan is a while loop: its body is
        # counted once by cost analysis -> scale to the full step.
        extrap[k] *= max(microbatches, 1)
    rec.update(extrap)
    rec["t_compute"] = extrap["hlo_flops"] / hw.peak_flops
    # memory term, two bounds: HLO bytes-accessed is pre-fusion (upper bound);
    # live bytes (params+opt+cache+activations touched once) is the lower.
    rec["t_memory_hlo"] = extrap["hlo_bytes"] / hw.hbm_bw
    rec["t_memory"] = rec["bytes_per_device"] / hw.hbm_bw
    rec["t_collective"] = extrap["collective_bytes"] / hw.link_bw
    dominant = max(("t_compute", "t_memory", "t_collective"), key=lambda k: rec[k])
    rec["bottleneck"] = dominant
    tmax = rec[dominant]
    rec["roofline_fraction_compute"] = rec["t_compute"] / tmax if tmax > 0 else 0.0
    mf = model_flops(cfg, shape, per_device=True, n_chips=n_chips)
    rec["model_flops_per_device"] = mf
    rec["useful_fraction"] = mf / extrap["hlo_flops"] if extrap["hlo_flops"] else 0.0
    return rec


# ---------------------------------------------------------------------------
# PARAFAC2 cells (the paper's workload on the production mesh)
# ---------------------------------------------------------------------------

def parafac2_specs(K: int, J: int, R: int, geometry, dp: int,
                   opts: Optional[Parafac2Options] = None,
                   format: str = "cc"):
    """ShapeDtypeStruct Bucketed + state for a dataset geometry
    [(Kb, I_pad, C_pad, N_pad)...]; Kb rounded up to the DP shard count.
    ``format="scoo"`` lowers the O(nnz) flat-COO layout (N_pad triplets per
    subject) instead of the densified CC rectangle. ADMM-routed constraints
    in ``opts`` add their carried ``(Z, U)`` dual pairs to the state's aux
    pytree (bucketed-W aux follows the bucket shapes)."""
    from repro.core.parafac2 import constraints_for

    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    K = ((K + dp - 1) // dp) * dp   # pad subject count to the DP shard count
    bf16 = jnp.bfloat16
    buckets = []
    for kb, ip, cp, npad in geometry:
        kb = ((kb + dp - 1) // dp) * dp
        common = dict(
            cols=sds((kb, cp), i32),
            col_mask=sds((kb, cp), f32),
            subject_ids=sds((kb,), i32),
            subject_mask=sds((kb,), f32),
            row_counts=sds((kb,), i32),
        )
        if format == "scoo":
            buckets.append(SparseBucket(
                vals=sds((kb, npad), bf16),   # bf16 triplet values, f32 accum
                rows=sds((kb, npad), i32),
                lcols=sds((kb, npad), i32),
                row_ends=sds((kb, ip), i32),
                cperm=sds((kb, npad), i32),
                col_ends=sds((kb, cp), i32),
                nnz_counts=sds((kb,), i32),
                n_rows_pad=ip,
                **common,
            ))
        else:
            buckets.append(Bucket(
                vals=sds((kb, ip, cp), bf16),   # bf16 slice values, f32 accum
                **common,
            ))
    data = Bucketed(buckets=buckets, n_subjects=K, n_cols=J, norm_sq=1.0)
    cons = constraints_for(opts) if opts is not None else None

    def aux_for(mode, shape):
        if cons is None or not cons[mode].admm:
            return ()
        return (sds(shape, f32), sds(shape, f32))    # (Z, U) dual pair

    aux = {"h": aux_for("h", (R, R)), "v": aux_for("v", (J, R)),
           "w": ([aux_for("w", (b.vals.shape[0], R)) for b in buckets]
                 if cons is not None and cons["w"].admm else ())}
    state = Parafac2State(
        H=sds((R, R), f32), V=sds((J, R), f32),
        W=tuple(sds((b.vals.shape[0], R), f32) for b in buckets),  # bucketed W
        fit=sds((), f32), aux=aux)
    return data, state


def parafac2_shardings(data: Bucketed, state, mesh: Mesh, *, wide: bool = True):
    """wide=True: subjects shard over EVERY mesh axis (pod x data x model) —
    the paper's workload has no tensor-parallel dimension, so leaving "model"
    idle wastes 16x memory/compute capacity (§Perf 'subject-wide sharding')."""
    axes = tuple(mesh.axis_names) if wide else _dp_axes(mesh)
    # every bucket leaf (CC or SCOO) is Kb-leading -> split over the subject
    # axes; tree_map keeps the Bucket/SparseBucket pytree structure intact
    kb = NamedSharding(mesh, P(axes))
    d_sh = jax.tree_util.tree_map(lambda _: kb, data)
    rep = NamedSharding(mesh, P())
    subj = NamedSharding(mesh, P(axes))
    # ADMM aux shardings follow the owning factor: bucketed-W duals split
    # over the subject axes, H/V duals replicate
    aux_sh = {k: jax.tree_util.tree_map(lambda _: subj if k == "w" else rep,
                                        sub)
              for k, sub in state.aux.items()} if isinstance(state.aux, dict) \
        else jax.tree_util.tree_map(lambda _: rep, state.aux)
    s_sh = Parafac2State(
        H=rep,
        V=rep,                             # replicated-V mode (J moderate)
        W=tuple(subj for _ in data.buckets),
        fit=rep, aux=aux_sh)
    return d_sh, s_sh


PARAFAC2_CELLS = {
    # name: (K, J, R, [(Kb_per_bucket, I_pad, C_pad, N_pad)...]) — CHOA /
    # synth-500M. N_pad is the SCOO per-subject triplet pad (≈4-8 nonzeros
    # per observation row — EHR-like ~1-3% intra-slice density); the CC
    # lowering ignores it.
    "parafac2-choa-r40": (464_900, 1_328, 40,
                          [(116_225, 32, 64, 128), (116_225, 64, 96, 256),
                           (116_225, 96, 128, 384), (116_225, 168, 256, 672)]),
    "parafac2-synth500m-r40": (1_000_000, 5_000, 40,
                               [(250_000, 48, 256, 384), (250_000, 64, 384, 512),
                                (250_000, 80, 512, 640), (250_000, 104, 640, 832)]),
}


def run_parafac2_cell(name: str, mesh: Mesh, mesh_name: str, hw=TPU_V5E,
                      backend: str = "jnp", engine: str = "host",
                      check_every: int = 8, constraint: str = "",
                      format: str = "cc", compress: str = "none",
                      precision: str = "f32"):
    """Lower + compile one PARAFAC2 cell. ``engine`` selects what one
    dispatch is: a single als_step ("host" — today's per-iteration loop), a
    check_every-iteration lax.scan chunk under GSPMD ("scan"), or the same
    chunk wrapped in shard_map over the subjects axes ("mesh") — see
    repro.core.engine. ``format`` picks the device layout the cell lowers
    against: "cc" (densified rectangles) or "scoo" (O(nnz) flat COO — the
    sparse path's production program shape + roofline). ``constraint`` is
    the driver spec syntax ("v=nonneg_admm,w=nonneg_admm"); ADMM specs put
    the carried dual pytree into the lowered state so the production program
    shape includes the AO-ADMM solver state. ``compress`` is a
    repro.core.compress spec ("rsvd[:r[:p[:q]]]"): the cell then lowers the
    CORE geometry the compressed ALS iterates over — every bucket's row pad
    clamped to the sketch dimension S = r + p, always CC (cores are dense) —
    i.e. the program shape whose per-iteration roofline the DPar2-style
    stage buys."""
    from repro.core import engine as als_engine
    from repro.core.compress import parse_preprocess_spec
    from repro.core.constraints import parse_constraint_arg

    K, J, R, geom = PARAFAC2_CELLS[name]
    pp = parse_preprocess_spec(compress)
    if not pp.identity:
        S = pp.sketch_dim(R)
        # core ALS geometry: [Kb, min(I_pad, S), C_pad]; cores are dense CC
        geom = [(kb, min(ip, S), cp, npad) for kb, ip, cp, npad in geom]
        format = "cc"
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {"arch": name + ("+scoo" if format == "scoo" else "")
           + ("+rsvd" if not pp.identity else ""),
           "shape": "als_step", "mesh": mesh_name,
           "kind": "parafac2", "n_chips": n_chips, "params": 0,
           "active_params": 0, "backend": backend, "engine": engine,
           "format": format, "compress": pp.spec, "precision": precision}
    specs = (parse_constraint_arg(constraint) if constraint
             else {"v": "nonneg", "w": "nonneg"})
    rec["constraints"] = {m: s for m, s in specs.items()}
    opts = Parafac2Options(rank=R, constraints=specs, w_layout="bucketed",
                           backend=backend, precision=precision,
                           engine=engine, check_every=check_every)
    wide = rec.get("wide", True)
    dp = _axis_size(mesh, tuple(mesh.axis_names) if wide else ("pod", "data"))
    data, state = parafac2_specs(K, J, R, geom, dp, opts, format=format)
    d_sh, s_sh = parafac2_shardings(data, state, mesh, wide=wide)
    t0 = time.perf_counter()
    with axis_rules(LM_RULES, mesh), mesh:
        if engine == "host":
            step = jax.jit(
                lambda d, s: als_step(d, s, opts),
                in_shardings=(d_sh, s_sh), out_shardings=s_sh)
        elif engine == "scan":
            rec["check_every"] = check_every
            step = jax.jit(
                als_engine.als_chunk_fn(opts, check_every),
                in_shardings=(d_sh, s_sh),
                out_shardings=(s_sh, NamedSharding(mesh, P())))
        elif engine == "mesh":
            rec["check_every"] = check_every
            # shard_map defines the layouts itself; no jit in_shardings
            step = jax.jit(als_engine.mesh_wrap(
                als_engine.als_chunk_fn(opts, check_every), data, state,
                mesh=mesh))
        else:
            raise ValueError(engine)
        lowered = step.lower(data, state)
        rec["lower_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            rec[attr] = int(getattr(mem, attr, 0) or 0)
        rec["bytes_per_device"] = (
            rec["argument_size_in_bytes"] + rec["temp_size_in_bytes"])
        rec["fits_hbm_16g"] = rec["bytes_per_device"] <= 16 * 2**30
        terms = roofline_terms(compiled, hw=hw)
        rec.update(terms)
        rec["t_memory_hlo"] = terms["t_memory"]
        rec["t_memory"] = rec["bytes_per_device"] / hw.hbm_bw
        # flops per HLO byte accessed — the fused backend's whole point is
        # raising this (Y_k never round-trips HBM between stages; bf16/f16
        # precision additionally halves every streamed slab byte)
        rec["arithmetic_intensity"] = (
            terms["hlo_flops"] / terms["hlo_bytes"]
            if terms.get("hlo_bytes") else 0.0)
        dominant = max(("t_compute", "t_memory", "t_collective"),
                       key=lambda k: rec[k])
        rec["bottleneck"] = dominant
        # useful work: the SPARTan flop count (Procrustes + 3 MTTKRPs +
        # grams). CC pays the densified rectangle; SCOO's O(nnz) roofline
        # counts only the padded triplets (the benchmarks/roofline_report.py
        # entry for the sparse path).
        if format == "scoo":
            cells = sum(kb * npad for kb, ip, cp, npad in geom)
            # the padded triplet count IS the lowered nonzero capacity — the
            # "100M+-nnz geometry on a pod mesh" claim in one number
            rec["padded_nnz"] = int(cells)
        else:
            cells = sum(kb * ip * cp for kb, ip, cp, npad in geom)
        useful = (6.0 * cells * R + 10.0 * K * R * R) / n_chips
        rec["model_flops_per_device"] = useful
        rec["useful_fraction"] = useful / terms["hlo_flops"] if terms["hlo_flops"] else 0.0
        # model-side streamed-slab traffic per iteration, precision-aware
        # (bf16/f16 slabs move 2 bytes/cell, f32 moves 4). The staged route
        # reads the vals slab twice (X_k V, projection) and round-trips the
        # compact Yc three more times (write + mode-2 + ykv reads); the
        # fused route re-reads vals three times and never materializes Yc —
        # the arithmetic-intensity gap the megakernel exists for.
        if format != "scoo":
            val_b = 2                       # parafac2_specs lowers bf16 vals
            slab_b = 2 if precision in ("bf16", "f16") else 4
            yc_cells = sum(kb * R * cp for kb, ip, cp, npad in geom)
            if backend == "fused":
                streamed = 3.0 * cells * val_b
            else:
                streamed = 2.0 * cells * val_b + 3.0 * yc_cells * slab_b
            rec["model_streamed_bytes_per_device"] = streamed / n_chips
            rec["model_arithmetic_intensity"] = (
                useful / (streamed / n_chips) if streamed else 0.0)
    return rec


# ---------------------------------------------------------------------------
# sweep driver with JSON result cache
# ---------------------------------------------------------------------------

def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, default=float)
    os.replace(tmp, path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.normpath(RESULTS_PATH))
    ap.add_argument("--parafac2", action="store_true", help="also run paper-workload cells")
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "scoo", "fused", "auto"],
                    help="MTTKRP backend for the PARAFAC2 cells (the host "
                         "placeholder mesh lowers pallas/fused in interpret "
                         "mode)")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "f16"],
                    help="compute precision for the PARAFAC2 cells: bf16/f16 "
                         "stage the streamed slab operands half-width with "
                         "f32 accumulation — halves the roofline's streamed "
                         "bytes (repro.kernels.common)")
    ap.add_argument("--format", default="cc", choices=["cc", "scoo"],
                    help="device data format the PARAFAC2 cells lower "
                         "against: cc (densified rectangles) or scoo (the "
                         "O(nnz) flat-COO path; N_pad from PARAFAC2_CELLS)")
    ap.add_argument("--engine", default="host", choices=["host", "scan", "mesh"],
                    help="ALS execution engine for the PARAFAC2 cells: what "
                         "one lowered dispatch is (see repro.core.engine)")
    ap.add_argument("--check-every", type=int, default=8,
                    help="scan-chunk length for --engine scan/mesh")
    ap.add_argument("--compress", default="none",
                    help="preprocessing spec for the PARAFAC2 cells "
                         "(repro.core.compress, e.g. 'rsvd:80:8:1'): lowers "
                         "the compressed CORE geometry (row pads clamped to "
                         "the sketch dim, CC format) instead of the full "
                         "data — the program shape the core ALS iterates on")
    ap.add_argument("--constraint", default="",
                    help="constraint spec for the PARAFAC2 cells "
                         "(driver syntax, e.g. 'v=nonneg_admm,w=nonneg_admm'); "
                         "empty = legacy nonneg. The sweep ALWAYS additionally "
                         "lowers one AO-ADMM-constrained cell per mesh.")
    ap.add_argument("--sp", action="store_true", help="sequence-parallel residual stream (hillclimb)")
    ap.add_argument("--remat-policy", default="", help="override cfg.remat_policy (hillclimb)")
    ap.add_argument("--microbatches", type=int, default=1, help="gradient accumulation (train cells)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    results = load_results(args.out)
    from repro.launch.summary import run_summary
    meta = results.setdefault("_meta", {})
    meta["flops_convention"] = calibrate_flops_convention(meshes[0][1])
    # the unified driver schema block (repro.launch.summary); cells carry
    # their own resolved knobs, so the options block here stays empty
    meta.update(run_summary("dryrun", None))

    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = applicable_shapes(cfg) if args.shape == "all" else [args.shape]
            for shape_name in shapes:
                key = (f"{arch}|{shape_name}|{mesh_name}" + ("+sp" if args.sp else "")
                       + (f"+{args.remat_policy}" if args.remat_policy else "")
                       + (f"+mb{args.microbatches}" if args.microbatches > 1 else ""))
                if key in results and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   roofline=(mesh_name == "pod16x16"), sp=args.sp,
                                   remat_policy=args.remat_policy,
                                   microbatches=args.microbatches)
                    results[key] = rec
                    save_results(args.out, results)
                    detail = (f"t_comp={rec['t_compute']*1e3:.2f}ms "
                              f"t_mem={rec['t_memory']*1e3:.2f}ms "
                              f"t_coll={rec['t_collective']*1e3:.2f}ms "
                              f"bottleneck={rec['bottleneck']} "
                              if "t_compute" in rec else "")
                    print(f"[dryrun] {key}: OK {detail}"
                          f"bytes/dev={rec['bytes_per_device']/2**30:.2f}GiB "
                          f"(compile {rec['compile_s']:.0f}s)", flush=True)
                except Exception as e:  # a failing cell is a bug to fix
                    failures.append((key, repr(e)))
                    print(f"[dryrun] {key}: FAIL {e}", flush=True)
                    if not args.quiet:
                        traceback.print_exc()
        if args.parafac2:
            # every cell with the requested constraint, plus at least one
            # AO-ADMM-constrained cell per mesh (the carried dual state must
            # lower + compile on the production meshes, not just on CPU)
            admm_spec = "v=nonneg_admm,w=nonneg_admm"
            cells = [(cell, args.constraint, "") for cell in PARAFAC2_CELLS]
            if args.constraint != admm_spec:
                cells.append((next(iter(PARAFAC2_CELLS)), admm_spec, "+admm"))
            for cell, cons, tag in cells:
                key = (f"{cell}|als_step|{mesh_name}"
                       + (f"+{args.format}" if args.format != "cc" else "")
                       + (f"+{args.backend}" if args.backend != "jnp" else "")
                       + (f"+{args.precision}" if args.precision != "f32"
                          else "")
                       + (f"+{args.engine}" if args.engine != "host" else "")
                       + (f"+[{cons}]" if cons else "")
                       + (f"+[{args.compress}]" if args.compress != "none"
                          else "")
                       + tag)
                if key in results and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_parafac2_cell(cell, mesh, mesh_name,
                                            backend=args.backend,
                                            engine=args.engine,
                                            check_every=args.check_every,
                                            constraint=cons,
                                            format=args.format,
                                            compress=args.compress,
                                            precision=args.precision)
                    results[key] = rec
                    save_results(args.out, results)
                    print(f"[dryrun] {key}: OK bottleneck={rec['bottleneck']} "
                          f"(compile {rec['compile_s']:.0f}s)", flush=True)
                except Exception as e:
                    failures.append((key, repr(e)))
                    print(f"[dryrun] {key}: FAIL {e}", flush=True)
                    if not args.quiet:
                        traceback.print_exc()

    n_ok = len([k for k in results if not k.startswith("_")])
    print(f"[dryrun] done: {n_ok} cells recorded, {len(failures)} failures")
    for k, e in failures:
        print(f"  FAIL {k}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
