"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).

Axis names ("pod", "data", "model") are the PHYSICAL side of the logical
axis-rule tables in :mod:`repro.dist.sharding` — install a mesh with
``axis_rules(LM_RULES, mesh)`` and the models' logical `shard` annotations
resolve onto it (see docs/ARCHITECTURE.md, stage 5).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a 1-D data mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
