"""PARAFAC2 decomposition driver — the paper's workload as a first-class job.

  PYTHONPATH=src python -m repro.launch.decompose --dataset choa --scale 0.002 \
      --rank 5 --iters 20 --engine scan --format auto --json out.json \
      --constraint v=nonneg+l1:0.1,w=smooth:0.1

``--engine`` picks the ALS execution engine (host | scan | mesh — see
repro.core.engine); ``--format`` the device data format (cc | scoo | auto —
repro.core.irregular; "auto" routes each bucket CC-vs-SCOO by measured
density, the O(nnz) sparse path for EHR-like sparsity); ``--constraint`` the
per-mode factor constraints (COPA-style AO-ADMM layer — see
repro.core.constraints; a bare spec such as ``--constraint nonneg_admm``
applies to both V and W); ``--compress`` the preprocessing stage
(repro.core.compress; ``rsvd[:r[:p[:q]]]`` runs the whole ALS on randomized
small cores and expands exactly at the end — the DPar2-style decoupling of
iteration count from data size); ``--json`` writes the machine-readable run
summary CI and the benchmarks consume (the unified ``schema_version`` +
``resolved_options`` layout of repro.launch.summary), including the resolved
constraint/compress blocks and the per-bucket format/density decisions.

Fault tolerance (repro.dist.supervisor; scan/mesh engines): ``--ckpt-dir``
checkpoints every ``--ckpt-every`` chunks and ``--resume`` continues from
the newest one (restore-then-continue is bitwise under scan).
``--fail-at "1,3:5"`` injects transient faults at chunk boundaries (an
optional ``:times`` > ``--max-retries`` exhausts the in-place retries and
forces the checkpoint-restore path); ``--nan-at`` poisons a chunk's state
with NaNs so the numerical-health sentinel rolls back. A faulted run
re-converges to the SAME factors as an unfaulted one, and the
retry/restore/rollback counts land in the ``--json`` summary's
``supervisor`` block. ``--supervise`` engages the supervisor without any
faults (e.g. for checkpoint cadence alone). Under ``--engine mesh`` the
bucket plan is additionally nnz-BALANCED across the subject shards
(BucketPlan.balance_for_shards — equal nonzeros per shard, not equal
subjects), with the per-shard nnz and the residual imbalance reported.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ENGINES, FORMATS, Parafac2Options, bucketize, fit
from repro.core.compress import available as available_preprocess
from repro.core.constraints import (
    available as available_constraints, constraint_summary, parse_constraint_arg)
from repro.core.interpret import subject_top_phenotypes, top_phenotype_features
from repro.data import choa_like, movielens_like
from repro.dist.fault import FaultInjector
from repro.dist.supervisor import SupervisorConfig, supervised_fit
from repro.launch.summary import resolved_options, run_summary
from repro.sparse import plan_buckets, random_irregular, route_formats


def parse_fail_spec(spec: str) -> dict:
    """``"1,3:5"`` -> ``{1: 1, 3: 5}``: comma-separated chunk indices, each
    with an optional ``:times`` count (how many attempts fault before the
    injected failure clears — times > --max-retries forces a restore)."""
    out = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            if ":" in part:
                step, times = part.split(":", 1)
                out[int(step)] = int(times)
            else:
                out[int(part)] = 1
        except ValueError:
            raise ValueError(
                f"bad fault spec {part!r} (want CHUNK or CHUNK:TIMES, "
                f"e.g. '1,3:5')") from None
    return out


def load_dataset(name: str, scale: float, seed: int):
    if name == "choa":
        return choa_like(scale=scale, seed=seed)
    if name == "movielens":
        return movielens_like(scale=scale, seed=seed)
    if name == "synthetic":
        return random_irregular(
            n_subjects=max(16, int(10_000 * scale)), n_cols=5_000,
            max_rows=100, avg_nnz_per_subject=500, seed=seed)
    raise ValueError(name)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="choa", choices=["choa", "movielens", "synthetic"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--rank", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--constraint", default="", metavar="SPECS",
                    help="per-mode factor constraints, e.g. "
                         "'v=nonneg+l1:0.1,w=smooth:0.1' (modes h/v/w; a bare "
                         "spec applies to v and w; registered: "
                         f"{', '.join(available_constraints())} — see "
                         "repro.core.constraints). Default: the paper's "
                         "nonneg V/W.")
    ap.add_argument("--compress", default="none", metavar="SPEC",
                    help="preprocessing stage (repro.core.compress): "
                         f"registered: {', '.join(available_preprocess())}. "
                         "'rsvd[:r[:p[:q]]]' compresses every tall bucket to "
                         "randomized cores (rank r, default 2*rank; "
                         "oversampling p; q power iterations), runs the core "
                         "ALS, and expands exactly at the end")
    ap.add_argument("--backend", default="auto",
                    choices=["jnp", "pallas", "scoo", "fused", "auto"],
                    help="MTTKRP compute backend for the ALS hot loop "
                         "(see repro.core.backend; 'fused' runs the fused "
                         "ALS megakernel stages — Y_k never materialized)")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "f16"],
                    help="compute precision for the streamed operands: "
                         "bf16/f16 stage slab values half-width while every "
                         "dot accumulates f32 (repro.kernels.common)")
    ap.add_argument("--format", default="cc", choices=list(FORMATS),
                    help="device data format (repro.core.irregular): cc "
                         "(dense over kept columns), scoo (O(nnz) flat COO), "
                         "auto (route each bucket by measured density)")
    ap.add_argument("--engine", default="host", choices=list(ENGINES),
                    help="ALS execution engine: host (per-iteration dispatch), "
                         "scan (device-resident compiled chunks), mesh "
                         "(scan + shard_map over subjects — see repro.core.engine)")
    ap.add_argument("--check-every", type=int, default=10,
                    help="iterations per device dispatch for scan/mesh "
                         "(0 = single-dispatch lax.while_loop convergence)")
    ap.add_argument("--tol", type=float, default=1e-7,
                    help="fit-change convergence tolerance")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable run summary to PATH")
    ap.add_argument("--buckets", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    # --- fault-tolerant supervisor (repro.dist.supervisor) -----------------
    ap.add_argument("--supervise", action="store_true",
                    help="run the fit under the fault-tolerant supervisor "
                         "even without faults/checkpointing (scan/mesh only; "
                         "faultless supervised runs are bitwise the bare fit "
                         "under scan)")
    ap.add_argument("--ckpt-dir", default="", metavar="DIR",
                    help="checkpoint directory: write elastic checkpoints "
                         "every --ckpt-every chunks (repro.checkpoint)")
    ap.add_argument("--ckpt-every", type=int, default=1, metavar="N",
                    help="chunks between checkpoint writes (with --ckpt-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint in --ckpt-dir "
                         "(restore-then-continue is bitwise under scan)")
    ap.add_argument("--fail-at", default="", metavar="SPEC",
                    help="inject transient faults at these chunk boundaries: "
                         "'1,3:5' = a blip at chunk 1, a 5-times fault at "
                         "chunk 3 (times > --max-retries forces the "
                         "checkpoint-restore path)")
    ap.add_argument("--nan-at", default="", metavar="SPEC",
                    help="poison the state with NaNs at these chunk "
                         "boundaries (same SPEC syntax as --fail-at); the "
                         "health sentinel rolls back to the last good "
                         "checkpoint")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="in-place retries per chunk before escalating to "
                         "checkpoint-restore")
    ap.add_argument("--backoff", type=float, default=0.0,
                    help="base retry backoff seconds (exponential, "
                         "deterministic seeded jitter — repro.dist.fault)")
    args = ap.parse_args(argv)

    fail_spec = parse_fail_spec(args.fail_at)
    nan_spec = parse_fail_spec(args.nan_at)
    supervise = (args.supervise or bool(args.ckpt_dir) or args.resume
                 or bool(fail_spec) or bool(nan_spec))
    if supervise and args.engine not in ("scan", "mesh"):
        raise SystemExit(
            "--supervise/--ckpt-dir/--resume/--fail-at/--nan-at need the "
            "chunked device engines: pass --engine scan or --engine mesh")
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir")

    if args.constraint:
        # raises ValueError listing the registered constraints on a bad spec
        specs = parse_constraint_arg(args.constraint)
    else:
        specs = {"v": "nonneg", "w": "nonneg"}     # the paper's default
    print(f"[constraints] {constraint_summary(specs)}")

    t0 = time.perf_counter()
    data = load_dataset(args.dataset, args.scale, args.seed)
    print(f"[data] K={data.n_subjects} J={data.n_cols} nnz={data.nnz} "
          f"({time.perf_counter()-t0:.1f}s)")

    # shard_map needs every bucket's subject count to divide the shard count
    subject_align = len(jax.devices()) if args.engine == "mesh" else 1
    rc, ccnt, nnzc = data.row_counts(), data.col_counts(), data.nnz_counts()
    plan = plan_buckets(rc, ccnt, max_buckets=args.buckets, nnz_counts=nnzc,
                        sort_by="nnz" if args.format == "scoo" else "area")
    shard_balance = None
    if args.engine == "mesh" and subject_align > 1:
        # nnz-balance the subject shards: equal nonzeros per contiguous
        # shard chunk, not equal subject counts — the quantile planner sorts
        # members by size, which would put every heavy subject on the last
        # shard (the straggler the watchdog would then flag forever)
        naive = plan.shard_imbalance(nnzc, subject_align)
        plan = plan.balance_for_shards(nnzc, subject_align)
        shard_balance = {
            "n_shards": subject_align,
            "shard_nnz": plan.shard_nnz(nnzc, subject_align),
            "imbalance_max_over_mean": plan.shard_imbalance(
                nnzc, subject_align),
            "imbalance_unbalanced": naive,
        }
        print(f"[shard-balance] {subject_align} shards: imbalance "
              f"{naive:.3f} -> "
              f"{shard_balance['imbalance_max_over_mean']:.3f} (max/mean nnz)")
    fmts = route_formats(plan, nnzc, format=args.format)
    bt = bucketize(data, dtype=jnp.float32, subject_align=subject_align,
                   plan=plan, formats=fmts)
    bucket_stats = plan.stats(rc, ccnt, nnzc, formats=fmts)
    for rec, b in zip(bucket_stats, bt.buckets):
        rec["device_bytes"] = int(sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(b)))
    device_bytes = sum(rec["device_bytes"] for rec in bucket_stats)
    print(f"[bucketize] {len(bt.buckets)} buckets ({args.format}): "
          + ", ".join(f"{r['format']}@{r['density']*100:.1f}%"
                      for r in bucket_stats)
          + f"; device bytes {device_bytes/2**20:.1f} MiB")

    # raises ValueError listing the registered preprocessors on a bad spec
    opts = Parafac2Options(rank=args.rank, constraints=specs, backend=args.backend,
                           precision=args.precision,
                           engine=args.engine, check_every=args.check_every,
                           compress=args.compress)
    t0 = time.perf_counter()
    supervisor_report = None
    if supervise:
        injector = (FaultInjector(fail_spec, nan_steps=nan_spec)
                    if (fail_spec or nan_spec) else None)
        cfg = SupervisorConfig(
            max_retries=args.max_retries, backoff=args.backoff,
            jitter=0.1 if args.backoff else 0.0,
            ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
            resume=args.resume, injector=injector)
        state, hist, report = supervised_fit(
            bt, opts, max_iters=args.iters, tol=args.tol, seed=args.seed,
            verbose=True, config=cfg)
        supervisor_report = report.as_dict()
        print(f"[supervisor] retries={report.retries} "
              f"restores={report.restores} rollbacks={report.rollbacks} "
              f"stragglers={len(report.stragglers)} "
              f"checkpoints={report.checkpoints_written}")
    else:
        state, hist = fit(bt, opts, max_iters=args.iters, tol=args.tol,
                          seed=args.seed, verbose=True)
    dt = time.perf_counter() - t0
    print(f"[fit] {len(hist)} iters in {dt:.1f}s "
          f"({dt/max(len(hist),1):.2f}s/iter), fit={hist[-1]:.4f}")

    phen = top_phenotype_features(np.asarray(state.V), top=5)
    for r, feats in enumerate(phen):
        print(f"phenotype {r}: " + ", ".join(f"{n}({w:.2f})" for n, w in feats[:5]))
    print("subject 0 top phenotypes:", subject_top_phenotypes(np.asarray(state.W), 0))
    V_np = np.asarray(state.V)
    summary = run_summary(
        "decompose",
        # the canonicalized option block every driver shares (includes the
        # resolved constraint + compress specs)
        resolved_options(opts, format=args.format, tol=args.tol,
                         seed=args.seed),
        dataset=args.dataset, scale=args.scale, rank=args.rank,
        engine=args.engine, backend=args.backend, precision=args.precision,
        tol=args.tol,
        check_every=args.check_every, seed=args.seed,
        # device-format decisions: requested format + the per-bucket routing
        # (chosen format, density, nnz, padded shape, device bytes)
        format=args.format,
        buckets=bucket_stats,
        device_bytes=device_bytes,
        # resolved (canonicalized) per-mode constraint specs + the V sparsity
        # they induced — the l1 knob's observable effect
        constraints=constraint_summary(specs),
        compress=args.compress,
        v_zero_fraction=float((V_np == 0.0).mean()),
        n_subjects=data.n_subjects, n_cols=data.n_cols, nnz=data.nnz,
        fit=float(hist[-1]), fit_history=[float(f) for f in hist],
        iters=len(hist), seconds_total=dt,
        seconds_per_iter=dt / max(len(hist), 1),
        platform=jax.default_backend(),
        # fault-tolerance observability: retry/restore/rollback/straggler
        # counts (None when the supervisor was not engaged) + the mesh
        # engine's per-shard nnz balance
        supervisor=supervisor_report,
        shard_balance=shard_balance,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"[json] wrote {args.json}")
    return summary


if __name__ == "__main__":
    main()
