"""PARAFAC2 decomposition driver — the paper's workload as a first-class job.

  PYTHONPATH=src python -m repro.launch.decompose --dataset choa --scale 0.002 \
      --rank 5 --iters 20 --engine scan --json out.json \
      --constraint v=nonneg+l1:0.1,w=smooth:0.1

``--engine`` picks the ALS execution engine (host | scan | mesh — see
repro.core.engine); ``--constraint`` the per-mode factor constraints
(COPA-style AO-ADMM layer — see repro.core.constraints; a bare spec such as
``--constraint nonneg_admm`` applies to both V and W); ``--json`` writes the
machine-readable run summary CI and the benchmarks consume, including the
resolved constraint block.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ENGINES, Parafac2Options, bucketize, fit
from repro.core.constraints import (
    available as available_constraints, constraint_summary, parse_constraint_arg)
from repro.core.interpret import subject_top_phenotypes, top_phenotype_features
from repro.data import choa_like, movielens_like
from repro.sparse import random_irregular


def load_dataset(name: str, scale: float, seed: int):
    if name == "choa":
        return choa_like(scale=scale, seed=seed)
    if name == "movielens":
        return movielens_like(scale=scale, seed=seed)
    if name == "synthetic":
        return random_irregular(
            n_subjects=max(16, int(10_000 * scale)), n_cols=5_000,
            max_rows=100, avg_nnz_per_subject=500, seed=seed)
    raise ValueError(name)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="choa", choices=["choa", "movielens", "synthetic"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--rank", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--nonneg", action=argparse.BooleanOptionalAction, default=True,
                    help="DEPRECATED (use --constraint): nonnegativity on V/W "
                         "(disable with --no-nonneg)")
    ap.add_argument("--constraint", default="", metavar="SPECS",
                    help="per-mode factor constraints, e.g. "
                         "'v=nonneg+l1:0.1,w=smooth:0.1' (modes h/v/w; a bare "
                         "spec applies to v and w; registered: "
                         f"{', '.join(available_constraints())} — see "
                         "repro.core.constraints). Overrides --nonneg.")
    ap.add_argument("--backend", default="auto", choices=["jnp", "pallas", "auto"],
                    help="MTTKRP compute backend for the ALS hot loop "
                         "(see repro.core.backend)")
    ap.add_argument("--engine", default="host", choices=list(ENGINES),
                    help="ALS execution engine: host (per-iteration dispatch), "
                         "scan (device-resident compiled chunks), mesh "
                         "(scan + shard_map over subjects — see repro.core.engine)")
    ap.add_argument("--check-every", type=int, default=10,
                    help="iterations per device dispatch for scan/mesh "
                         "(0 = single-dispatch lax.while_loop convergence)")
    ap.add_argument("--tol", type=float, default=1e-7,
                    help="fit-change convergence tolerance")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable run summary to PATH")
    ap.add_argument("--buckets", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.constraint:
        # raises ValueError listing the registered constraints on a bad spec
        specs = parse_constraint_arg(args.constraint)
    else:
        nn = "nonneg" if args.nonneg else "none"
        specs = {"v": nn, "w": nn}
    print(f"[constraints] {constraint_summary(specs)}")

    t0 = time.perf_counter()
    data = load_dataset(args.dataset, args.scale, args.seed)
    print(f"[data] K={data.n_subjects} J={data.n_cols} nnz={data.nnz} "
          f"({time.perf_counter()-t0:.1f}s)")

    # shard_map needs every bucket's subject count to divide the shard count
    subject_align = len(jax.devices()) if args.engine == "mesh" else 1
    bt = bucketize(data, max_buckets=args.buckets, dtype=jnp.float32,
                   subject_align=subject_align)
    waste = 1.0 - data.nnz / sum(
        int(np.prod(b.vals.shape)) for b in bt.buckets)
    print(f"[bucketize] {len(bt.buckets)} buckets; padded-cell occupancy "
          f"{(1-waste)*100:.1f}% nnz")

    opts = Parafac2Options(rank=args.rank, constraints=specs, backend=args.backend,
                           engine=args.engine, check_every=args.check_every)
    t0 = time.perf_counter()
    state, hist = fit(bt, opts, max_iters=args.iters, tol=args.tol,
                      seed=args.seed, verbose=True)
    dt = time.perf_counter() - t0
    print(f"[fit] {len(hist)} iters in {dt:.1f}s "
          f"({dt/max(len(hist),1):.2f}s/iter), fit={hist[-1]:.4f}")

    phen = top_phenotype_features(np.asarray(state.V), top=5)
    for r, feats in enumerate(phen):
        print(f"phenotype {r}: " + ", ".join(f"{n}({w:.2f})" for n, w in feats[:5]))
    print("subject 0 top phenotypes:", subject_top_phenotypes(np.asarray(state.W), 0))
    V_np = np.asarray(state.V)
    summary = {
        "dataset": args.dataset, "scale": args.scale, "rank": args.rank,
        "engine": args.engine, "backend": args.backend, "tol": args.tol,
        "check_every": args.check_every, "seed": args.seed,
        # resolved (canonicalized) per-mode constraint specs + the V sparsity
        # they induced — the l1 knob's observable effect
        "constraints": constraint_summary(specs),
        "v_zero_fraction": float((V_np == 0.0).mean()),
        "n_subjects": data.n_subjects, "n_cols": data.n_cols, "nnz": data.nnz,
        "fit": float(hist[-1]), "fit_history": [float(f) for f in hist],
        "iters": len(hist), "seconds_total": dt,
        "seconds_per_iter": dt / max(len(hist), 1),
        "platform": jax.default_backend(),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"[json] wrote {args.json}")
    return summary


if __name__ == "__main__":
    main()
