"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device   / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw_per_chip

cost_analysis() of an SPMD-partitioned module reports the *per-device*
program (verified by `calibrate_flops_convention`), so chips appear in the
denominator implicitly. MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) is
the useful-work yardstick; MODEL/HLO ratio flags remat & redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.analysis.hlo import collective_bytes

__all__ = ["HW", "TPU_V5E", "roofline_terms", "model_flops", "calibrate_flops_convention"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # per chip, bf16
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # bytes/s per ICI link


TPU_V5E = HW(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


def _cost_get(cost: Any, key: str) -> float:
    if isinstance(cost, dict):
        return float(cost.get(key, 0.0))
    if isinstance(cost, (list, tuple)) and cost:
        return _cost_get(cost[0], key)
    return 0.0


def roofline_terms(compiled, *, hw: HW = TPU_V5E,
                   hlo_text: Optional[str] = None) -> Dict[str, float]:
    """Three roofline terms (seconds) + raw counters from a compiled module."""
    cost = compiled.cost_analysis()
    flops = _cost_get(cost, "flops")
    bytes_accessed = _cost_get(cost, "bytes accessed")
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    terms = {
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collective_bytes": float(coll["total"]),
        "collective_breakdown": {k: v for k, v in coll.items() if k != "total"},
        "t_compute": flops / hw.peak_flops,
        "t_memory": bytes_accessed / hw.hbm_bw,
        "t_collective": coll["total"] / hw.link_bw,
    }
    dominant = max(("t_compute", "t_memory", "t_collective"), key=lambda k: terms[k])
    terms["bottleneck"] = dominant
    tmax = terms[dominant]
    # roofline fraction: useful ceiling / achievable step time if perfectly
    # overlapped (bounded by the dominant term)
    terms["roofline_fraction_compute"] = (
        terms["t_compute"] / tmax if tmax > 0 else 0.0)
    return terms


def model_flops(cfg, shape, *, per_device: bool = True, n_chips: int = 1) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) for a train step; 2*N*D for a
    forward-only (prefill) step; 2*N_active per token for decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips if per_device else total


def calibrate_flops_convention(mesh) -> str:
    """Empirically decide whether cost_analysis flops are per-device or global
    for SPMD modules (JAX version dependent). Returns 'per_device'|'global'."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = 1
    for s in mesh.devices.shape:
        n *= s
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a):
        return a @ a

    sharded = jax.jit(
        f, in_shardings=NamedSharding(mesh, P(mesh.axis_names[0], None))
    ).lower(x).compile()
    local = jax.jit(f).lower(x).compile()
    fs = _cost_get(sharded.cost_analysis(), "flops")
    fl = _cost_get(local.cost_analysis(), "flops")
    if fs <= 0 or fl <= 0:
        return "unknown"
    return "per_device" if fs < 0.75 * fl else "global"
