"""Lightweight HLO-text parser: collective-communication byte accounting.

cost_analysis() has no collective term, so we parse the (post-SPMD) HLO from
``compiled.as_text()``: build a symbol table of instruction result shapes,
then for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute sum the *operand* byte sizes (bytes each device injects
into the interconnect for that op).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["collective_bytes", "parse_collectives", "shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = f32[1,2,3]{...} op-name(...)` (also tuple results `(f32[..], ...)`)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """Returns [(op_kind, operand_bytes)] per collective instruction."""
    shapes: Dict[str, int] = {}
    results: List[Tuple[str, int]] = []
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if not m:
            continue
        name, is_tuple, dtype, dims = m.groups()
        if is_tuple:
            # tuple result: sum all shape literals before the op name
            header = line.split("=", 1)[1]
            header = header.split(")", 1)[0]
            total = sum(shape_bytes(dt, dm)
                        for dt, dm in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", header))
            shapes[name] = total
        else:
            shapes[name] = shape_bytes(dtype, dims)
        body = line.split("=", 1)[1]
        for op in _COLLECTIVES:
            # match the op at the start of the instruction body (after shapes)
            if re.search(rf"\b{op}(?:-start|-done)?\(", body):
                if f"{op}-done" in body:
                    continue  # async pair: bytes counted at -start
                args = body.split("(", 1)[1]
                operand_names = _OPERAND.findall(args.split("),", 1)[0])
                obytes = sum(shapes.get(a, 0) for a in operand_names)
                if obytes == 0:
                    # operands may be literal-shaped (e.g. `all-gather(f32[2] %x)`)
                    obytes = sum(shape_bytes(dt, dm) for dt, dm in
                                 re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", args))
                results.append((op, obytes))
                break
    return results


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total operand bytes per collective kind + 'total'."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for op, b in parse_collectives(hlo_text):
        out[op] += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
