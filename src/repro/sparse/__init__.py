from repro.sparse.coo import (
    IrregularCOO,
    SubjectCOO,
    from_dense_slices,
    random_irregular,
    random_parafac2,
)
from repro.sparse.bucketing import BucketPlan, plan_buckets

__all__ = [
    "IrregularCOO",
    "SubjectCOO",
    "from_dense_slices",
    "random_irregular",
    "random_parafac2",
    "BucketPlan",
    "plan_buckets",
]
