from repro.sparse.coo import (
    IrregularCOO,
    SubjectCOO,
    from_dense_slices,
    random_irregular,
    random_parafac2,
)
from repro.sparse.bucketing import (
    SCOO_DENSITY_THRESHOLD,
    BucketPlan,
    fixed_plan,
    plan_buckets,
    route_formats,
)

__all__ = [
    "IrregularCOO",
    "SubjectCOO",
    "from_dense_slices",
    "random_irregular",
    "random_parafac2",
    "BucketPlan",
    "fixed_plan",
    "plan_buckets",
    "route_formats",
    "SCOO_DENSITY_THRESHOLD",
]
