"""Size-bucketing planner: ragged subjects -> a few static-shape buckets.

XLA needs static shapes. Subjects vary in row count I_k, nonzero-column
count c_k, and nonzero count nnz_k; we group them into buckets whose padded
geometry is chosen to bound padding waste while keeping the number of
distinct compiled shapes small. Pad targets are rounded up to multiples of
``row_align`` / ``col_align`` (8 / 128 by default — TPU sublane/lane quanta;
the 128 lane default is what the Pallas MTTKRP kernels' alignment assumption
and the ``auto`` backend's kernel-friendly check expect). Pass a smaller
``col_align`` explicitly for CPU-only runs where padding waste matters more
than lane alignment.

Two padding currencies, one per device format (repro.core.irregular):

* **area** — the CC format densifies each slice over its kept columns, so a
  bucket costs ``Kb * I_pad * C_pad`` cells regardless of the true nonzero
  count. ``padding_waste`` measures this.
* **nnz** — the SCOO format stores flat per-subject triplets padded to the
  bucket's ``N_pad`` (``nnz_pads``), so a bucket costs ``Kb * N_pad``
  entries. ``nnz_waste`` measures this; pass ``nnz_counts`` (and, for
  SCOO-heavy data, ``sort_by="nnz"``) to plan it.

``route_formats`` turns the per-bucket *density* — true nonzeros over the
densified CC cell count, the quantity that decides which format is cheaper —
into a per-bucket "cc"/"scoo" decision (the ``bucketize(format="auto")``
router).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["BucketPlan", "fixed_plan", "plan_buckets", "route_formats",
           "SCOO_DENSITY_THRESHOLD"]


def _shard_capacities(n_members: int, n_shards: int) -> List[int]:
    """Real-subject slots per shard under ``bucketize``'s layout: the bucket
    pads Kb up to a multiple of `n_shards` with padding slots at the TAIL,
    and shard s then owns the contiguous slots [s*cs, (s+1)*cs). Shards
    0..n-2 therefore hold exactly ``cs`` real subjects; the LAST shard
    absorbs all the padding."""
    cs = -(-n_members // n_shards)            # ceil -> padded Kb / n_shards
    return [max(0, min(cs, n_members - s * cs)) for s in range(n_shards)]

# Density below which the SCOO format wins over CC for a bucket: one SCOO
# nonzero costs ~3 staged entries (val + row + col) and ~2 gathers per
# contraction vs CC's 1 dense cell, so the crossover is well above 10%;
# 0.25 keeps CC for near-dense buckets (where the MXU-shaped dense matmul
# is unbeatable) and routes genuinely sparse buckets to the O(nnz) path.
SCOO_DENSITY_THRESHOLD = 0.25


def _round_up(x: int, align: int) -> int:
    return max(align, ((int(x) + align - 1) // align) * align)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Assignment of subject indices to padded-shape buckets."""

    # per bucket: (I_pad, C_pad) and the member subject indices
    shapes: List[tuple]          # [(I_pad, C_pad)]
    members: List[np.ndarray]    # [int32 arrays of subject ids]
    # per bucket: padded nonzero count N_pad (SCOO layout); None when the
    # plan was built without nnz_counts
    nnz_pads: Optional[List[int]] = None

    @property
    def n_buckets(self) -> int:
        return len(self.shapes)

    def padding_waste(self, row_counts: Sequence[int], col_counts: Sequence[int]) -> float:
        """Fraction of padded cells that are padding (area metric — the CC
        format's currency)."""
        used = 0
        total = 0
        for (ip, cp), mem in zip(self.shapes, self.members):
            for k in mem:
                used += int(row_counts[k]) * int(col_counts[k])
                total += ip * cp
        return 1.0 - used / max(total, 1)

    # -- nnz metrics (the SCOO format's currency + the format router's signal)
    def bucket_nnz(self, nnz_counts: Sequence[int]) -> List[int]:
        """True nonzero count per bucket."""
        nz = np.asarray(nnz_counts, dtype=np.int64)
        return [int(nz[mem].sum()) for mem in self.members]

    def bucket_densities(self, nnz_counts: Sequence[int]) -> List[float]:
        """Per-bucket density: true nonzeros over the densified CC cell count
        ``n_members * I_pad * C_pad`` — the CC-vs-SCOO routing signal."""
        return [
            nnz / max(len(mem) * ip * cp, 1)
            for (ip, cp), mem, nnz in zip(
                self.shapes, self.members, self.bucket_nnz(nnz_counts))
        ]

    def nnz_waste(self, nnz_counts: Sequence[int]) -> float:
        """Fraction of padded SCOO entries that are padding (needs a plan
        built with ``nnz_counts`` so ``nnz_pads`` is populated)."""
        if self.nnz_pads is None:
            raise ValueError("plan has no nnz_pads; pass nnz_counts to "
                             "plan_buckets to plan the SCOO layout")
        used = sum(self.bucket_nnz(nnz_counts))
        total = sum(npad * len(mem)
                    for npad, mem in zip(self.nnz_pads, self.members))
        return 1.0 - used / max(total, 1)

    # -- nnz-balanced sharding (the mesh engine's straggler planner) --------
    def balance_for_shards(self, nnz_counts: Sequence[int],
                           n_shards: int) -> "BucketPlan":
        """Reorder every bucket's members so the `n_shards` contiguous
        subject shards carry (near-)equal NONZERO counts, not equal subject
        counts.

        Under ``engine="mesh"`` each bucket's leading axis splits into
        `n_shards` contiguous chunks (``bucketize(subject_align=n_shards)``
        pads at the tail — see :func:`_shard_capacities`); with quantile
        bucketing the members arrive sorted by size, so naive order puts all
        the heavy subjects on the last shards and the per-chunk SCOO work
        (O(bucket nnz / n_shards) only if balanced) stragglers. This is
        capacity-constrained greedy LPT: walk subjects by nnz descending,
        assign each to the least-loaded shard with a free slot (ties -> the
        lowest shard index, so the result is deterministic). The short
        final shard (the one holding the padding) gets the fewest slots.

        Shapes and pad targets are untouched — only the order WITHIN each
        bucket changes, so the padded geometry (and therefore the compiled
        program) is identical; only the subject->slot assignment moves.
        """
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        nz = np.asarray(nnz_counts, dtype=np.int64)
        if n_shards == 1:
            return self
        new_members = []
        for mem in self.members:
            caps = _shard_capacities(len(mem), n_shards)
            loads = [0] * n_shards
            bins: List[list] = [[] for _ in range(n_shards)]
            # stable sort on -nnz: equal-nnz subjects keep member order
            order = mem[np.argsort(-nz[mem], kind="stable")]
            for k in order:
                s = min((s for s in range(n_shards) if len(bins[s]) < caps[s]),
                        key=lambda s: (loads[s], s))
                bins[s].append(k)
                loads[s] += int(nz[k])
            new_members.append(
                np.concatenate([np.asarray(b, dtype=np.int32) for b in bins
                                if b]) if len(mem) else mem)
        return dataclasses.replace(self, members=new_members)

    def shard_nnz(self, nnz_counts: Sequence[int],
                  n_shards: int) -> List[List[int]]:
        """Per-bucket per-shard true nonzero counts under the contiguous
        chunk layout (tail padding) — the balance the planner above
        optimizes, surfaced so drivers can report it."""
        nz = np.asarray(nnz_counts, dtype=np.int64)
        out = []
        for mem in self.members:
            caps = _shard_capacities(len(mem), n_shards)
            loads, lo = [], 0
            for c in caps:
                loads.append(int(nz[mem[lo:lo + c]].sum()))
                lo += c
            out.append(loads)
        return out

    def shard_imbalance(self, nnz_counts: Sequence[int],
                        n_shards: int) -> float:
        """max/mean per-shard nnz over all buckets combined (1.0 = perfectly
        balanced; the straggler factor an unbalanced plan pays)."""
        per_bucket = self.shard_nnz(nnz_counts, n_shards)
        totals = [sum(b[s] for b in per_bucket) for s in range(n_shards)]
        mean = sum(totals) / max(len(totals), 1)
        return max(totals) / mean if mean > 0 else 1.0

    def stats(self, row_counts: Sequence[int], col_counts: Sequence[int],
              nnz_counts: Sequence[int],
              formats: Optional[Sequence[str]] = None) -> List[dict]:
        """Per-bucket records (shape, members, nnz, density, chosen format) —
        what ``decompose.py --json`` surfaces."""
        out = []
        nnzs = self.bucket_nnz(nnz_counts)
        dens = self.bucket_densities(nnz_counts)
        for i, ((ip, cp), mem) in enumerate(zip(self.shapes, self.members)):
            rec = {
                "i_pad": ip, "c_pad": cp, "n_subjects": len(mem),
                "nnz": nnzs[i], "density": dens[i],
            }
            if self.nnz_pads is not None:
                rec["nnz_pad"] = self.nnz_pads[i]
            if formats is not None:
                rec["format"] = formats[i]
            out.append(rec)
        return out


def plan_buckets(
    row_counts: Sequence[int],
    col_counts: Sequence[int],
    *,
    max_buckets: int = 4,
    row_align: int = 8,
    col_align: int = 128,
    nnz_counts: Optional[Sequence[int]] = None,
    nnz_align: int = 8,
    sort_by: str = "area",
) -> BucketPlan:
    """Greedy quantile bucketing on (I_k, c_k[, nnz_k]).

    Sort subjects by padded cost and split into ``max_buckets`` contiguous
    groups of (roughly) equal count; each bucket pads to its member max.
    Simple, deterministic, and bounds waste well for the skewed long-tail
    distributions typical of EHR data.

    ``sort_by`` picks the cost the quantiles equalize: ``"area"`` (I_k * c_k,
    the CC format's padded-cell currency — the default) or ``"nnz"`` (the
    SCOO format's padded-triplet currency; needs ``nnz_counts``). With
    ``nnz_counts`` given, every bucket also gets its SCOO pad target
    ``N_pad = round_up(max member nnz, nnz_align)`` in ``plan.nnz_pads``.
    """
    rc = np.asarray(row_counts, dtype=np.int64)
    cc = np.asarray(col_counts, dtype=np.int64)
    if rc.shape != cc.shape or rc.ndim != 1 or rc.size == 0:
        raise ValueError("row_counts/col_counts must be equal-length 1-D, non-empty")
    nz = None
    if nnz_counts is not None:
        nz = np.asarray(nnz_counts, dtype=np.int64)
        if nz.shape != rc.shape:
            raise ValueError("nnz_counts must match row_counts in length")
    if sort_by == "area":
        key = rc * cc
    elif sort_by == "nnz":
        if nz is None:
            raise ValueError("sort_by='nnz' needs nnz_counts")
        key = nz
    else:
        raise ValueError(f"unknown sort_by {sort_by!r}; choose 'area' or 'nnz'")
    n = rc.size
    order = np.argsort(key, kind="stable")
    n_buckets = int(min(max_buckets, n))
    splits = np.array_split(order, n_buckets)
    shapes, members = [], []
    for grp in splits:
        if grp.size == 0:
            continue
        ip = _round_up(int(rc[grp].max()), row_align)
        cp = _round_up(int(cc[grp].max()), col_align)
        shapes.append((ip, cp))
        members.append(grp.astype(np.int32))
    # merge buckets that ended up with identical shapes (compile-shape dedupe)
    merged: dict = {}
    for s, m in zip(shapes, members):
        if s in merged:
            merged[s] = np.concatenate([merged[s], m])
        else:
            merged[s] = m
    shapes = list(merged.keys())
    members = [merged[s] for s in shapes]
    nnz_pads = None
    if nz is not None:
        nnz_pads = [_round_up(int(nz[mem].max()), nnz_align) if mem.size else
                    nnz_align for mem in members]
    return BucketPlan(shapes=shapes, members=members, nnz_pads=nnz_pads)


def fixed_plan(
    n_subjects: int,
    i_pad: int,
    c_pad: int,
    *,
    nnz_pad: Optional[int] = None,
) -> BucketPlan:
    """A single-bucket plan with an EXPLICIT padded geometry.

    The quantile planner above picks shapes from the data, so two batches
    with different member geometry compile two different programs. The
    streaming service (``launch/stream.py``) instead pins one
    ``(I_pad, C_pad[, N_pad])`` rectangle chosen up front and pads every
    request batch into it — each flush then re-dispatches the SAME compiled
    update regardless of which subjects arrived. Members are simply
    ``0..n_subjects-1``: the caller stages exactly the batch's subjects.

    Raises ``ValueError`` downstream (in ``bucketize``) if a subject exceeds
    the pinned nnz budget; row/col overflow must be checked by the caller
    (the service grows its sticky geometry and recompiles).
    """
    if n_subjects < 1 or i_pad < 1 or c_pad < 1:
        raise ValueError("fixed_plan needs n_subjects, i_pad, c_pad >= 1")
    return BucketPlan(
        shapes=[(int(i_pad), int(c_pad))],
        members=[np.arange(n_subjects, dtype=np.int32)],
        nnz_pads=None if nnz_pad is None else [int(nnz_pad)],
    )


def route_formats(
    plan: BucketPlan,
    nnz_counts: Sequence[int],
    *,
    format: str = "auto",
    density_threshold: float = SCOO_DENSITY_THRESHOLD,
) -> List[str]:
    """Per-bucket device-format decision for ``bucketize``.

    ``format="cc"``/``"scoo"`` force every bucket; ``"auto"`` routes each
    bucket by its measured density (true nonzeros over the densified CC cell
    count): below ``density_threshold`` the O(nnz) SCOO path wins, at or
    above it the dense-over-kept-columns CC matmuls do.
    """
    if format in ("cc", "scoo"):
        return [format] * plan.n_buckets
    if format != "auto":
        raise ValueError(
            f"unknown format {format!r}; choose from 'cc', 'scoo', 'auto'")
    return ["scoo" if d < density_threshold else "cc"
            for d in plan.bucket_densities(nnz_counts)]


def route_compress(shapes, sketch_dim: int) -> List[bool]:
    """Per-bucket pass-through decision for the rsvd preprocessing stage
    (:mod:`repro.core.compress`): compress a bucket only when its padded row
    space exceeds the sketch width — otherwise the "core" would be as large
    as the data and the QB pass pure overhead.

    ``shapes`` is a list of ``(i_pad, c_pad)`` pairs (``BucketPlan.shapes``
    or the realized buckets' padded shapes); returns one bool per bucket.
    """
    if isinstance(shapes, BucketPlan):
        shapes = shapes.shapes
    if sketch_dim < 1:
        raise ValueError(f"sketch_dim must be >= 1, got {sketch_dim}")
    return [int(ip) > int(sketch_dim) for ip, _ in shapes]
