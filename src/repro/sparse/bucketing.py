"""Size-bucketing planner: ragged subjects -> a few static-shape buckets.

XLA needs static shapes. Subjects vary in row count I_k and nonzero-column
count c_k; we group them into buckets whose padded (I_pad, C_pad) geometry is
chosen to bound padding waste while keeping the number of distinct compiled
shapes small. Pad targets are rounded up to multiples of ``row_align`` /
``col_align`` (8 / 128 by default — TPU sublane/lane quanta; the 128 lane
default is what the Pallas MTTKRP kernels' alignment assumption and the
``auto`` backend's kernel-friendly check expect). Pass a smaller
``col_align`` explicitly for CPU-only runs where padding waste matters more
than lane alignment.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

__all__ = ["BucketPlan", "plan_buckets"]


def _round_up(x: int, align: int) -> int:
    return max(align, ((int(x) + align - 1) // align) * align)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Assignment of subject indices to padded-shape buckets."""

    # per bucket: (I_pad, C_pad) and the member subject indices
    shapes: List[tuple]          # [(I_pad, C_pad)]
    members: List[np.ndarray]    # [int32 arrays of subject ids]

    @property
    def n_buckets(self) -> int:
        return len(self.shapes)

    def padding_waste(self, row_counts: Sequence[int], col_counts: Sequence[int]) -> float:
        """Fraction of padded cells that are padding (area metric)."""
        used = 0
        total = 0
        for (ip, cp), mem in zip(self.shapes, self.members):
            for k in mem:
                used += int(row_counts[k]) * int(col_counts[k])
                total += ip * cp
        return 1.0 - used / max(total, 1)


def plan_buckets(
    row_counts: Sequence[int],
    col_counts: Sequence[int],
    *,
    max_buckets: int = 4,
    row_align: int = 8,
    col_align: int = 128,
) -> BucketPlan:
    """Greedy quantile bucketing on (I_k, c_k).

    Sort subjects by padded area and split into ``max_buckets`` contiguous
    groups of (roughly) equal count; each bucket pads to its member max.
    Simple, deterministic, and bounds waste well for the skewed long-tail
    distributions typical of EHR data.
    """
    rc = np.asarray(row_counts, dtype=np.int64)
    cc = np.asarray(col_counts, dtype=np.int64)
    if rc.shape != cc.shape or rc.ndim != 1 or rc.size == 0:
        raise ValueError("row_counts/col_counts must be equal-length 1-D, non-empty")
    n = rc.size
    order = np.argsort(rc * cc, kind="stable")
    n_buckets = int(min(max_buckets, n))
    splits = np.array_split(order, n_buckets)
    shapes, members = [], []
    for grp in splits:
        if grp.size == 0:
            continue
        ip = _round_up(int(rc[grp].max()), row_align)
        cp = _round_up(int(cc[grp].max()), col_align)
        shapes.append((ip, cp))
        members.append(grp.astype(np.int32))
    # merge buckets that ended up with identical shapes (compile-shape dedupe)
    merged: dict = {}
    for s, m in zip(shapes, members):
        if s in merged:
            merged[s] = np.concatenate([merged[s], m])
        else:
            merged[s] = m
    shapes = list(merged.keys())
    members = [merged[s] for s in shapes]
    return BucketPlan(shapes=shapes, members=members)
