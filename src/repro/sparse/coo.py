"""COO utilities and deterministic random irregular-tensor generators.

An *irregular tensor* is a collection ``{X_k in R^{I_k x J}}`` of K sparse
matrices sharing the variables axis J but with ragged observation counts I_k.
On the host side we represent it as a list of per-subject COO triplets; the
device-side formats live in :mod:`repro.core.irregular`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "SubjectCOO",
    "IrregularCOO",
    "random_irregular",
    "random_parafac2",
    "from_dense_slices",
]


@dataclasses.dataclass(frozen=True)
class SubjectCOO:
    """One subject's sparse slice X_k (I_k x J) in COO."""

    rows: np.ndarray  # int32 [nnz]
    cols: np.ndarray  # int32 [nnz]
    vals: np.ndarray  # float  [nnz]
    n_rows: int       # I_k
    n_cols: int       # J (shared)

    @property
    def nnz(self) -> int:
        return int(self.vals.size)

    def nonzero_cols(self) -> np.ndarray:
        """Sorted unique column indices with at least one nonzero."""
        return np.unique(self.cols)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.float64)
        np.add.at(out, (self.rows, self.cols), self.vals)
        return out


@dataclasses.dataclass(frozen=True)
class IrregularCOO:
    """Host-side irregular tensor: K ragged sparse slices over shared J."""

    subjects: List[SubjectCOO]
    n_cols: int  # J

    @property
    def n_subjects(self) -> int:
        return len(self.subjects)

    @property
    def nnz(self) -> int:
        return sum(s.nnz for s in self.subjects)

    def row_counts(self) -> np.ndarray:
        return np.asarray([s.n_rows for s in self.subjects], dtype=np.int32)

    def col_counts(self) -> np.ndarray:
        return np.asarray([s.nonzero_cols().size for s in self.subjects], dtype=np.int32)

    def nnz_counts(self) -> np.ndarray:
        """Per-subject nonzero counts (the SCOO planner's padding currency)."""
        return np.asarray([s.nnz for s in self.subjects], dtype=np.int64)

    def frobenius_sq(self) -> float:
        return float(sum(np.sum(np.square(s.vals, dtype=np.float64)) for s in self.subjects))


def from_dense_slices(slices: Sequence[np.ndarray]) -> IrregularCOO:
    """Build an IrregularCOO from a list of dense I_k x J arrays."""
    if not slices:
        raise ValueError("need at least one slice")
    J = slices[0].shape[1]
    subs = []
    for X in slices:
        if X.shape[1] != J:
            raise ValueError("all slices must share the J (columns) axis")
        r, c = np.nonzero(X)
        subs.append(
            SubjectCOO(
                rows=r.astype(np.int32),
                cols=c.astype(np.int32),
                vals=X[r, c].astype(np.float64),
                n_rows=X.shape[0],
                n_cols=J,
            )
        )
    return IrregularCOO(subjects=subs, n_cols=J)


def random_irregular(
    *,
    n_subjects: int,
    n_cols: int,
    max_rows: int,
    avg_nnz_per_subject: float,
    seed: int = 0,
    min_rows: int = 1,
    nonneg: bool = True,
) -> IrregularCOO:
    """Uniform random sparse irregular tensor (synthetic-scaling experiments).

    Mirrors the paper's synthetic setup: every kept row has >= 1 nonzero
    (rows with no nonzeros are filtered by construction, as the paper notes).
    """
    rng = np.random.default_rng(seed)
    subs = []
    for _ in range(n_subjects):
        I_k = int(rng.integers(min_rows, max_rows + 1))
        lam = max(avg_nnz_per_subject, I_k)
        nnz = max(I_k, int(rng.poisson(lam)))
        # guarantee each row has at least one nonzero, rest uniform.
        rows = np.concatenate([np.arange(I_k), rng.integers(0, I_k, nnz - I_k)])
        cols = rng.integers(0, n_cols, nnz)
        vals = rng.random(nnz) if nonneg else rng.standard_normal(nnz)
        # dedupe (r, c) pairs by summing.
        key = rows.astype(np.int64) * n_cols + cols
        uk, inv = np.unique(key, return_inverse=True)
        v = np.zeros(uk.size)
        np.add.at(v, inv, vals)
        subs.append(
            SubjectCOO(
                rows=(uk // n_cols).astype(np.int32),
                cols=(uk % n_cols).astype(np.int32),
                vals=v,
                n_rows=I_k,
                n_cols=n_cols,
            )
        )
    return IrregularCOO(subjects=subs, n_cols=n_cols)


def random_parafac2(
    *,
    n_subjects: int,
    n_cols: int,
    max_rows: int,
    rank: int,
    density: float,
    seed: int = 0,
    nonneg: bool = True,
    noise: float = 0.0,
) -> Tuple[IrregularCOO, dict]:
    """Random low-rank PARAFAC2 model, then sparsified uniformly at random.

    This is the paper's synthetic-data protocol (Section 5.2): construct the
    factors of a rank-R PARAFAC2 model, build the slices {X_k}, then sparsify.
    Returns the data plus the ground-truth factors for recovery tests.
    """
    rng = np.random.default_rng(seed)
    sample = rng.random if nonneg else rng.standard_normal
    H = sample((rank, rank))
    V = sample((n_cols, rank))
    W = np.abs(rng.standard_normal((n_subjects, rank))) + 0.1
    subs = []
    for k in range(n_subjects):
        I_k = int(rng.integers(max(2, rank), max_rows + 1))
        # random column-orthonormal Q_k
        A = rng.standard_normal((I_k, rank))
        Q, _ = np.linalg.qr(A)
        Xk = (Q @ H) @ np.diag(W[k]) @ V.T
        if noise > 0:
            Xk = Xk + noise * rng.standard_normal(Xk.shape) * np.abs(Xk).mean()
        mask = rng.random(Xk.shape) < density
        Xk = np.where(mask, Xk, 0.0)
        keep = mask.any(axis=1)  # paper: filter all-zero rows
        Xk = Xk[keep]
        if Xk.shape[0] == 0:
            Xk = np.abs(sample((1, n_cols))) * (rng.random((1, n_cols)) < density)
        r, c = np.nonzero(Xk)
        subs.append(
            SubjectCOO(
                rows=r.astype(np.int32),
                cols=c.astype(np.int32),
                vals=Xk[r, c],
                n_rows=Xk.shape[0],
                n_cols=n_cols,
            )
        )
    truth = {"H": H, "V": V, "W": W}
    return IrregularCOO(subjects=subs, n_cols=n_cols), truth
