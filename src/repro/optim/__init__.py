from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, wsd_schedule
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import compressed_psum, dequantize, ef_compress_update, quantize

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "wsd_schedule",
    "clip_by_global_norm",
    "global_norm",
    "compressed_psum",
    "dequantize",
    "ef_compress_update",
    "quantize",
]
