"""Decoupled AdamW, pure pytree implementation.

First/second moments are f32 regardless of param dtype (bf16-safe); the
update is computed in f32 and cast back. State shards exactly like params
(path-based rules in repro.dist.sharding add the fsdp axis), giving ZeRO-style
optimizer-state partitioning under pjit for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim.clip import clip_by_global_norm

__all__ = ["AdamWState", "adamw_init", "adamw_update"]


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr,
    wd: float = 0.1,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    clip_norm: float = 1.0,
) -> Tuple[Any, AdamWState]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if clip_norm:
        grads, _ = clip_by_global_norm(grads, clip_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * (g * g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        if jnp.issubdtype(p.dtype, jnp.floating):
            delta = delta + wd * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
