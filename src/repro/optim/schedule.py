"""LR schedules: WSD (warmup-stable-decay, MiniCPM) and cosine."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["wsd_schedule", "cosine_schedule"]


def wsd_schedule(*, peak: float, warmup: int, total: int, decay_frac: float = 0.1,
                 floor: float = 0.0):
    """Warmup-Stable-Decay (arXiv:2404.06395): linear warmup, long stable
    plateau at `peak`, then a short exponential-style decay tail."""
    decay_steps = max(1, int(total * decay_frac))
    stable_end = total - decay_steps

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        tail = peak * jnp.exp(-5.0 * (step - stable_end) / decay_steps)
        lr = jnp.where(step < warmup, warm,
                       jnp.where(step < stable_end, peak, jnp.maximum(tail, floor)))
        return lr

    return sched


def cosine_schedule(*, peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return sched
