"""Error-feedback int8 gradient compression for cross-pod (DCN) all-reduce.

Quantize each gradient leaf to int8 with a per-leaf f32 scale, all-reduce the
int8 payload (8x less DCN traffic), dequantize, and keep the quantization
residual as error feedback added to the next step's gradient — the standard
EF-SGD construction that preserves convergence.

`compressed_psum` is the shard_map collective; `quantize`/`dequantize` are
pure and unit-tested on a single device.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "ef_compress_update", "compressed_psum"]


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8 payload, f32 scale). Symmetric per-tensor quantization."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grad: jax.Array, error: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One error-feedback step: returns (payload, scale, decoded, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    q, s = quantize(corrected)
    decoded = dequantize(q, s)
    new_error = corrected - decoded
    return q, s, decoded, new_error


def compressed_psum(grads: Any, errors: Any, axis_name: str):
    """shard_map-compatible compressed all-reduce with error feedback.

    Quantizes each leaf, psums the int8 payloads (as int32 accumulators to
    avoid overflow across >127 participants), dequantizes with the psum'd
    scale-sum, and returns (reduced_grads, new_errors).
    """

    def leaf(g, e):
        q, s, _, new_e = ef_compress_update(g, e)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_sum = jax.lax.psum(s, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # average of per-shard dequantized grads (scales averaged)
        return acc.astype(jnp.float32) * (s_sum / n) / n, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
