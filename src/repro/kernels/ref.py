"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's exact interface; kernel tests sweep shapes
and dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mode1_ref", "mode2_compact_ref", "mode3_ref", "gather_matmul_ref"]


def mode1_ref(Yc: jax.Array, Vg: jax.Array, Wb: jax.Array) -> jax.Array:
    """sum_k (Y_k V) * W(k,:)  ->  [R, R].

    Yc [K, R, C] compressed slices; Vg [K, C, R] gathered V rows; Wb [K, R].
    Padded subjects must arrive zeroed (mask pre-applied), as the kernel
    accumulates unconditionally.
    """
    YkV = jnp.einsum("krc,kcl->krl", Yc, Vg, preferred_element_type=jnp.float32)
    return jnp.einsum("krl,kl->rl", YkV, Wb.astype(jnp.float32))


def mode2_compact_ref(Yc: jax.Array, H: jax.Array, Wb: jax.Array) -> jax.Array:
    """A[k] = (Y_k^T H) * W(k,:)  ->  [K, C, R] (compact mode-2 stage)."""
    A = jnp.einsum("krc,rl->kcl", Yc, H, preferred_element_type=jnp.float32)
    return A * Wb[:, None, :].astype(jnp.float32)


def mode3_ref(Yc: jax.Array, Vg: jax.Array, H: jax.Array) -> jax.Array:
    """M3 rows: out[k,:] = coldot(H, Y_k V)  ->  [K, R]."""
    YkV = jnp.einsum("krc,kcl->krl", Yc, Vg, preferred_element_type=jnp.float32)
    return jnp.einsum("rl,krl->kl", H.astype(jnp.float32), YkV)


def gather_matmul_ref(vals: jax.Array, blk_ids: jax.Array, V: jax.Array) -> jax.Array:
    """BCC X_k V: vals [K, I, NB, L], blk_ids [K, NB], V [J_pad, R] with
    J_pad % L == 0. Padded blocks must be zero-valued (mask pre-applied).
    Returns [K, I, R]."""
    K, I, NB, L = vals.shape
    R = V.shape[1]
    V_blocks = V.reshape(-1, L, R)                       # [J_pad/L, L, R]
    Vg = V_blocks[blk_ids]                               # [K, NB, L, R]
    return jnp.einsum("kinl,knlr->kir", vals, Vg, preferred_element_type=jnp.float32)
