"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's exact interface; kernel tests sweep shapes
and dtypes and assert_allclose against these.

Accumulation follows :func:`repro.kernels.common.accum_dtype`: f64 inputs
accumulate (and return) f64, sub-f32 inputs accumulate f32 — the oracles must
not silently downgrade the f64 algebra the exactness tests rely on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import accum_dtype

__all__ = [
    "ykv_ref",
    "mode1_ref",
    "mode1_reuse_ref",
    "mode2_compact_ref",
    "mode3_ref",
    "mode3_reuse_ref",
    "gather_matmul_ref",
]


def mode1_ref(Yc: jax.Array, Vg: jax.Array, Wb: jax.Array) -> jax.Array:
    """sum_k (Y_k V) * W(k,:)  ->  [R, R].

    Yc [K, R, C] compressed slices; Vg [K, C, R] gathered V rows; Wb [K, R].
    Padded subjects must arrive zeroed (mask pre-applied), as the kernel
    accumulates unconditionally.
    """
    acc = accum_dtype(Yc)
    YkV = jnp.einsum("krc,kcl->krl", Yc, Vg, preferred_element_type=acc)
    return jnp.einsum("krl,kl->rl", YkV, Wb.astype(acc))


def ykv_ref(Yc: jax.Array, Vg: jax.Array) -> jax.Array:
    """YkV[k] = Y_k V  ->  [K, R, R] (the shared reuse product)."""
    return jnp.einsum("krc,kcl->krl", Yc, Vg,
                      preferred_element_type=accum_dtype(Yc))


def mode1_reuse_ref(YkV: jax.Array, Wb: jax.Array) -> jax.Array:
    """sum_k YkV_k * W(k,:) with YkV [K, R, R] pre-computed -> [R, R]."""
    acc = accum_dtype(YkV)
    return jnp.einsum("krl,kl->rl", YkV.astype(acc), Wb.astype(acc))


def mode2_compact_ref(Yc: jax.Array, H: jax.Array, Wb: jax.Array) -> jax.Array:
    """A[k] = (Y_k^T H) * W(k,:)  ->  [K, C, R] (compact mode-2 stage)."""
    acc = accum_dtype(Yc)
    A = jnp.einsum("krc,rl->kcl", Yc, H, preferred_element_type=acc)
    return A * Wb[:, None, :].astype(acc)


def mode3_ref(Yc: jax.Array, Vg: jax.Array, H: jax.Array) -> jax.Array:
    """M3 rows: out[k,:] = coldot(H, Y_k V)  ->  [K, R]."""
    acc = accum_dtype(Yc)
    YkV = jnp.einsum("krc,kcl->krl", Yc, Vg, preferred_element_type=acc)
    return jnp.einsum("rl,krl->kl", H.astype(acc), YkV)


def mode3_reuse_ref(YkV: jax.Array, H: jax.Array) -> jax.Array:
    """out[k,:] = coldot(H, YkV_k) with YkV [K, R, R] pre-computed -> [K, R]."""
    acc = accum_dtype(YkV)
    return jnp.einsum("rl,krl->kl", H.astype(acc), YkV.astype(acc))


def gather_matmul_ref(vals: jax.Array, blk_ids: jax.Array, V: jax.Array) -> jax.Array:
    """BCC X_k V: vals [K, I, NB, L], blk_ids [K, NB], V [J_pad, R] with
    J_pad % L == 0. Padded blocks must be zero-valued (mask pre-applied).
    Returns [K, I, R]."""
    K, I, NB, L = vals.shape
    R = V.shape[1]
    V_blocks = V.reshape(-1, L, R)                       # [J_pad/L, L, R]
    Vg = V_blocks[blk_ids]                               # [K, NB, L, R]
    return jnp.einsum("kinl,knlr->kir", vals, Vg,
                      preferred_element_type=accum_dtype(vals))
