"""Randomized range-finder stages for the rsvd preprocessing pass.

The DPar2-style compression (:mod:`repro.core.compress`) needs, per bucket,
an orthonormal basis P_k for the row space of every slice X_k [I_pad, J].
The classical randomized QB recipe (Halko/Martinsson/Tropp) is three stages,
and each one is already a bucket-level contraction this repo has fast paths
for:

  1. **sketch**   Y_k = X_k Ω with a shared Gaussian test matrix Ω [J, S]:
     exactly :meth:`Bucket.xk_times_v` — a gather of Ω's kept-column rows
     plus one tall-skinny [I_pad, C_pad] x [C_pad, S] matmul per subject, the
     MXU-friendly shape ``kernels/gather_matmul.py`` targets. On SCOO buckets
     the same call routes through the O(nnz) segment-sum kernels
     (:mod:`repro.kernels.scoo`), so sparse buckets are sketched WITHOUT ever
     densifying — the "SCOO-aware sketch".
  2. **power iteration** (q rounds, optional): Y <- X_k (X_k^T Y) sharpens
     the captured spectrum for slowly decaying singular values. Both halves
     are again existing stages: X_k^T Y is :meth:`Bucket.project` (landing in
     the compact kept-column layout) and the outer product is another
     ``xk_times_v`` with the gathered factor supplied directly.
  3. **orthonormalize** P_k = polar(Y_k) via the batched Gram-eigh polar
     factor (:func:`repro.core.procrustes.polar_gram_eigh`) — rank-deficient
     directions (padding subjects, slices with fewer than S independent
     rows) get exactly-zero basis columns instead of NaNs, which is the
     correct limit for the degenerate-slice case.

All stages are jit-compatible and batched over the bucket's Kb axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.procrustes import polar_gram_eigh

__all__ = ["gaussian_sketch", "sketch_bucket", "power_iterate", "range_basis"]


def gaussian_sketch(key: jax.Array, n_cols: int, sketch_dim: int,
                    dtype=jnp.float32) -> jax.Array:
    """Shared Gaussian test matrix Ω [J, S] (one draw for every bucket, so
    CC and SCOO buckets of the same data sketch against identical noise)."""
    return jax.random.normal(key, (n_cols, sketch_dim), dtype) / jnp.sqrt(
        jnp.asarray(sketch_dim, dtype))


def sketch_bucket(b, Omega: jax.Array,
                  Og: Optional[jax.Array] = None) -> jax.Array:
    """Y_k = X_k Ω for every subject in the bucket: [Kb, I_pad, S].

    ``b`` may be a CC :class:`~repro.core.irregular.Bucket` (dense tall-skinny
    matmul over kept columns) or a SCOO ``SparseBucket`` (gather + sorted
    segment-sum, O(nnz * S)) — the call is format-agnostic because only Ω
    rows of kept columns participate either way.
    """
    return b.xk_times_v(Omega, Og)


def power_iterate(b, Y: jax.Array, q: int) -> jax.Array:
    """q rounds of Y <- X_k (X_k^T Y), all in the compact kept-column space."""
    for _ in range(q):
        Z = b.project(Y)                           # [Kb, S, C_pad] compact
        Y = b.xk_times_v(None, Vg=jnp.swapaxes(Z, 1, 2))
    return Y


def range_basis(b, Omega: jax.Array, *, q: int = 1) -> jax.Array:
    """Orthonormal range basis P_k [Kb, I_pad, S] for every slice in ``b``.

    Columns beyond a slice's true rank come back exactly zero (pseudo-polar),
    and padding subjects get an all-zero basis via the subject mask.
    """
    Y = sketch_bucket(b, Omega)
    Y = power_iterate(b, Y, q)
    P = polar_gram_eigh(Y)
    return P * b.subject_mask[:, None, None]
