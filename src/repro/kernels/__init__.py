"""Pallas TPU kernels for the SPARTan MTTKRP hot spots (+ jnp oracles).

``ops``  — public jit'd wrappers (interpret=True off-TPU)
``ref``  — pure-jnp oracles (the correctness contract)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
