"""Pallas TPU kernel — SPARTan mode-1 MTTKRP.

Computes  M1 = sum_k (Y_k V) * W(k,:)  with the per-k R x C slice and the
gathered C x R V-rows streamed HBM -> VMEM, the R x C @ C x R product on the
MXU, the row-wise Hadamard with W(k,:) on the VPU, and the R x R accumulator
resident in the output VMEM window across the whole grid (classic revisited-
window reduction). Optionally tiles C for large kept-column counts.

Alignment: best MXU utilization wants R padded to 8 (sublane) and C to 128
(lane); the bucketizer's ``col_align=128`` produces that. Works (slower) for
odd shapes too; interpret=True is bit-exact on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mode1_pallas"]


def _kernel(yc_ref, vg_ref, wb_ref, out_ref):
    k = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when((k == 0) & (c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    yv = jnp.dot(yc_ref[0], vg_ref[0], preferred_element_type=jnp.float32)  # [R, R]
    out_ref[...] += yv * wb_ref[0][None, :]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def mode1_pallas(
    Yc: jax.Array,
    Vg: jax.Array,
    Wb: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Yc [K,R,C] (subject-mask pre-applied), Vg [K,C,R], Wb [K,R] -> [R,R]."""
    K, R, C = Yc.shape
    bc = min(block_c, C)
    nc = pl.cdiv(C, bc)
    if C % bc:  # zero-pad partial tile (zero columns contribute nothing)
        pad = nc * bc - C
        Yc = jnp.pad(Yc, ((0, 0), (0, 0), (0, pad)))
        Vg = jnp.pad(Vg, ((0, 0), (0, pad), (0, 0)))
    grid = (K, nc)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bc), lambda k, c: (k, 0, c)),
            pl.BlockSpec((1, bc, R), lambda k, c: (k, c, 0)),
            pl.BlockSpec((1, R), lambda k, c: (k, 0)),
        ],
        out_specs=pl.BlockSpec((R, R), lambda k, c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, R), jnp.float32),
        interpret=interpret,
    )(Yc, Vg, Wb)
