"""Pallas TPU kernel — SPARTan mode-1 MTTKRP.

Computes  M1 = sum_k (Y_k V) * W(k,:)  with the per-k R x C slice and the
gathered C x R V-rows streamed HBM -> VMEM, the R x C @ C x R product on the
MXU, the row-wise Hadamard with W(k,:) on the VPU, and the R x R accumulator
resident in the output VMEM window across the whole grid (classic revisited-
window reduction). Optionally tiles C for large kept-column counts.

Two entry points:

* :func:`mode1_pallas` — full gather+matmul path. ``subject_mask`` is folded
  into W(k,:) (the Hadamard is linear in W, so masking W masks the subject's
  whole contribution exactly).
* :func:`mode1_reuse_pallas` — the ``mode1_reuse`` path: Y_k V ([K,R,R]) is
  already cached from the Procrustes step (Y_k V = Q_k^T (X_k V)), so only
  the Hadamard + subject reduction remain (pure VPU work).

Alignment: best MXU utilization wants R padded to 8 (sublane) and C to 128
(lane); the bucketizer's ``col_align=128`` produces that. Works (slower) for
odd shapes too; interpret=True is bit-exact on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import accum_dtype, fold_subject_mask

__all__ = ["mode1_pallas", "mode1_reuse_pallas"]


def _kernel(yc_ref, vg_ref, wb_ref, out_ref, *, acc):
    k = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when((k == 0) & (c == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    yv = jnp.dot(yc_ref[0], vg_ref[0], preferred_element_type=acc)  # [R, R]
    out_ref[...] += yv * wb_ref[0].astype(acc)[None, :]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def mode1_pallas(
    Yc: jax.Array,
    Vg: jax.Array,
    Wb: jax.Array,
    subject_mask: Optional[jax.Array] = None,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Yc [K,R,C], Vg [K,C,R], Wb [K,R] -> [R,R]. ``subject_mask`` [K] (1.0 =
    real subject) is folded into Wb so padded subjects contribute nothing."""
    K, R, C = Yc.shape
    acc = accum_dtype(Yc)
    if K == 0:
        return jnp.zeros((R, R), acc)
    Wb = fold_subject_mask(Wb, subject_mask)
    bc = min(block_c, C)
    nc = pl.cdiv(C, bc)
    if C % bc:  # zero-pad partial tile (zero columns contribute nothing)
        pad = nc * bc - C
        Yc = jnp.pad(Yc, ((0, 0), (0, 0), (0, pad)))
        Vg = jnp.pad(Vg, ((0, 0), (0, pad), (0, 0)))
    grid = (K, nc)
    return pl.pallas_call(
        functools.partial(_kernel, acc=acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bc), lambda k, c: (k, 0, c)),
            pl.BlockSpec((1, bc, R), lambda k, c: (k, c, 0)),
            pl.BlockSpec((1, R), lambda k, c: (k, 0)),
        ],
        out_specs=pl.BlockSpec((R, R), lambda k, c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, R), acc),
        interpret=interpret,
    )(Yc, Vg, Wb)


def _reuse_kernel(ykv_ref, wb_ref, out_ref, *, acc):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ykv = ykv_ref[0].astype(acc)
    out_ref[...] += ykv * wb_ref[0].astype(acc)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mode1_reuse_pallas(
    YkV: jax.Array,
    Wb: jax.Array,
    subject_mask: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """YkV [K,R,R] (= Y_k V, cached), Wb [K,R] -> [R,R]: Hadamard with W(k,:)
    plus the subject-axis reduction only — the matmul was paid upstream."""
    K, R, _ = YkV.shape
    acc = accum_dtype(YkV)
    if K == 0:
        return jnp.zeros((R, R), acc)
    Wb = fold_subject_mask(Wb, subject_mask)
    return pl.pallas_call(
        functools.partial(_reuse_kernel, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, R, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, R), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((R, R), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, R), acc),
        interpret=interpret,
    )(YkV, Wb)
