"""Pallas TPU kernel — BCC gather-matmul  X_k V  with scalar-prefetched block ids.

The TPU-native replacement for sparse row-gather: column indices are quantized
to 128-wide blocks of J (BCC format, see repro.core.irregular). The per-subject
block-id list is a scalar-prefetch operand, so the BlockSpec ``index_map`` for
V *itself* selects which 128-row V block is DMA'd into VMEM — the gather is
performed by the memory system, not by compute. Padded blocks carry zero
values, so gathering V-block 0 for them is harmless.

  vals    [K, I, NB, L]  dense values per kept column-block (L = 128)
  blk_ids [K, NB]        global block index into V (scalar prefetch)
  V       [J_pad, R]     factor matrix, J_pad % L == 0
  out     [K, I, R]      X_k V
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import accum_dtype

__all__ = ["gather_matmul_pallas"]


def _kernel(blk_ref, vals_ref, v_ref, out_ref, *, nb: int, acc):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # vals block [1, I, 1, L] @ gathered V block [L, R]
    x = vals_ref[0, :, 0, :]                      # [I, L]
    out_ref[0] += jnp.dot(x, v_ref[...], preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_matmul_pallas(
    vals: jax.Array,
    blk_ids: jax.Array,
    V: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    K, I, NB, L = vals.shape
    J_pad, R = V.shape
    acc = accum_dtype(vals)
    if J_pad % L:
        raise ValueError(f"V rows ({J_pad}) must be a multiple of the lane width {L}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K, NB),
        in_specs=[
            pl.BlockSpec((1, I, 1, L), lambda k, b, blk: (k, 0, b, 0)),
            # the gather: V's block row is chosen by the prefetched id
            pl.BlockSpec((L, R), lambda k, b, blk: (blk[k, b], 0)),
        ],
        out_specs=pl.BlockSpec((1, I, R), lambda k, b, blk: (k, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, nb=NB, acc=acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, I, R), acc),
        interpret=interpret,
    )(blk_ids, vals, V)
