"""Fused Pallas ALS stages — one VMEM pass over each subject's CC slab.

The staged path round-trips every intermediate through HBM between separate
kernel launches: ``X_k V`` (xkv), ``B_k`` (Procrustes input), the projected
slices ``Y_k`` (project), and ``Y_k V`` (ykv) are each written by one stage
and re-read by the next. The fused stages here collapse that per bucket per
ALS iteration: each subject's kept-column slab ``vals[k]`` ([I_pad, C_pad],
the only large operand) is streamed through VMEM in double-buffered DMA
chunks and every product that touches it is computed in the same grid step,
so only the small per-subject results ([I,R] / [R,R] / [C,R]) ever reach HBM
— ``Y_k`` is NEVER materialized (the fused backend carries ``Q_k`` instead,
exactly like the SCOO-native route).

Why four launches and not one: exact Gauss-Seidel ALS parity admits at most
four fused dispatches per bucket per iteration, because the eigendecomposition
inside ``solve_q`` and the H-/V- normal-equation solves are global
synchronization points — ``Q_k`` depends on all of ``B_k``, the mode-2 stage
needs the UPDATED ``H``, and the ykv/fit stage needs the UPDATED ``V``. The
floor is

  F1 ``fused_procrustes_b``  xkv + B formation       (streams vals, 1st pass)
       --- eigh (solve_q) ---
  F2 ``fused_mode1_xkv``     YkV = Q^T XkV + M1 partial sum   ([I,R] operands)
       --- H solve ---
  F3 ``fused_mode2_compact`` project + mode-2 compact (streams vals, 2nd pass)
       --- V solve ---
  F4 ``fused_ykv``           project + Y_k V          (streams vals, 3rd pass)
  (mode-3 is a trivial [R,R] coldot on F4's output — no large operands left.)

versus the five streaming stage launches of the staged path (procrustes_b,
project, mode1-from-XkV, mode2, ykv). ``core.backend.dispatch_tally``
measures exactly this 5 -> 4 collapse.

Traffic tradeoff (documented, not hidden): fused reads ``vals`` three times
and writes no ``Y_k``; staged reads ``vals`` twice plus one write + two reads
of ``Y_k`` [R, C] and the XkV/B round-trips. Fused wins outright when
I_pad ≲ 3R — the compressed regime (``--compress rsvd:r`` cores have
I' ≈ r) — and on launch/round-trip overhead everywhere; with
``precision="bf16"`` the streamed slab bytes halve again while every dot
still accumulates in f32 (``preferred_element_type`` = ``accum_dtype``).

All wrappers accept f32/f64 (f64 accumulates f64 — unlike ``PallasBackend``
there is no silent demotion; Mosaic rejects f64 on real TPUs, but the fused
route is gated to f32/bf16 there by ``AutoBackend._fused_ok``) and bf16/f16
inputs (accumulate f32). ``interpret=True`` runs everywhere via the Pallas
interpreter — the CI parity path on CPU.

VMEM budget per grid step (one subject): the double buffer dominates at
``2 * I_pad * block_c * itemsize``; ``block_c`` is halved until it fits
``VMEM_BUDGET`` (8 MiB, leaving headroom for Vg [C_pad, R], the [I, R]
accumulator, and the output windows on a 16 MiB part).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import accum_dtype

__all__ = [
    "fused_procrustes_b",
    "fused_mode1_xkv",
    "fused_mode2_compact",
    "fused_ykv",
]

VMEM_BUDGET = 8 * 1024 * 1024  # double-buffer byte cap per grid step


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_c(C: int, I: int, itemsize: int, block_c: int) -> int:
    """Largest chunk width <= block_c whose double buffer fits VMEM_BUDGET."""
    bc = min(block_c, C)
    while bc > 128 and 2 * I * bc * itemsize > VMEM_BUDGET:
        bc //= 2
    return max(bc, 1)


def _pad_c(x: jax.Array, axis: int, C_pad: int) -> jax.Array:
    """Zero-pad axis ``axis`` to C_pad (zero columns contribute nothing)."""
    C = x.shape[axis]
    if C == C_pad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, C_pad - C)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# F1: xkv + Procrustes B formation (first slab pass)
# ---------------------------------------------------------------------------

def _procrustes_b_kernel(vals_hbm, vg_ref, wb_ref, h_ref, xkv_ref, b_ref,
                         vbuf, sem, *, nc: int, bc: int, acc):
    k = pl.program_id(0)
    I, R = xkv_ref.shape[1], xkv_ref.shape[2]

    def dma(slot, c):
        return pltpu.make_async_copy(
            vals_hbm.at[k, :, pl.ds(c * bc, bc)], vbuf.at[slot], sem.at[slot])

    dma(0, 0).start()

    def step(c, xkv):
        slot = jax.lax.rem(c, 2)

        @pl.when(c + 1 < nc)
        def _prefetch():
            dma(1 - slot, c + 1).start()

        dma(slot, c).wait()
        vg_c = vg_ref[0, pl.ds(c * bc, bc), :]            # [bc, R]
        return xkv + jnp.dot(vbuf[slot], vg_c, preferred_element_type=acc)

    xkv = jax.lax.fori_loop(0, nc, step, jnp.zeros((I, R), acc))
    xkv_ref[0] = xkv
    # B_k = (X_k V * w_k) H^T in the same dispatch — XkV never leaves VMEM
    # before its second use.
    w = wb_ref[0].astype(acc)
    b_ref[0] = jnp.dot(xkv * w[None, :], h_ref[...].astype(acc).T,
                       preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def fused_procrustes_b(
    vals: jax.Array,
    Vg: jax.Array,
    Wb: jax.Array,
    H: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = False,
):
    """vals [K,I,C], Vg [K,C,R], Wb [K,R], H [R,R] ->
    (XkV [K,I,R], B [K,I,R]) with B_k = (X_k V * w_k) H^T."""
    K, I, C = vals.shape
    R = Vg.shape[-1]
    acc = accum_dtype(vals)
    if K == 0:
        z = jnp.zeros((K, I, R), acc)
        return z, z
    bc = _pick_block_c(C, I, vals.dtype.itemsize, block_c)
    nc = pl.cdiv(C, bc)
    vals = _pad_c(vals, 2, nc * bc)
    Vg = _pad_c(Vg, 1, nc * bc)
    out = pl.pallas_call(
        functools.partial(_procrustes_b_kernel, nc=nc, bc=bc, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # vals: manual DMA
            pl.BlockSpec((1, nc * bc, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, R), lambda k: (k, 0)),
            pl.BlockSpec((R, R), lambda k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, I, R), acc),
            jax.ShapeDtypeStruct((K, I, R), acc),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, I, bc), vals.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(vals, Vg, Wb, H)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# F2: YkV = Q^T XkV + mode-1 partial sum (no slab pass — [I,R] operands)
# ---------------------------------------------------------------------------

def _mode1_xkv_kernel(q_ref, xkv_ref, wb_ref, out_ref, *, acc):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ykv = jnp.dot(q_ref[0].astype(acc).T, xkv_ref[0].astype(acc),
                  preferred_element_type=acc)             # [R, R]
    out_ref[...] += ykv * wb_ref[0].astype(acc)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mode1_xkv(
    Q: jax.Array,
    XkV: jax.Array,
    Wb: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Q [K,I,R], XkV [K,I,R], Wb [K,R] (subject mask pre-folded) ->
    partial M1 [R,R] = sum_k (Q_k^T X_k V) * w_k via the mode-1 reuse
    identity Y_k V = Q_k^T (X_k V): the per-subject YkV is formed and
    reduced in the same dispatch, never written back."""
    K, I, R = Q.shape
    acc = accum_dtype(Q)
    if K == 0:
        return jnp.zeros((R, R), acc)
    return pl.pallas_call(
        functools.partial(_mode1_xkv_kernel, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, R), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((R, R), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, R), acc),
        interpret=interpret,
    )(Q, XkV, Wb)


# ---------------------------------------------------------------------------
# F3: projection + mode-2 compact (second slab pass; Yc tiles stay in VMEM)
# ---------------------------------------------------------------------------

def _mode2_kernel(vals_hbm, q_ref, h_ref, wb_ref, cm_ref, out_ref,
                  vbuf, sem, *, nc: int, bc: int, acc):
    k = pl.program_id(0)

    def dma(slot, c):
        return pltpu.make_async_copy(
            vals_hbm.at[k, :, pl.ds(c * bc, bc)], vbuf.at[slot], sem.at[slot])

    dma(0, 0).start()
    q = q_ref[0].astype(acc)                              # [I, R]
    h = h_ref[...].astype(acc)                            # [R, R]
    w = wb_ref[0].astype(acc)                             # [R]

    def step(c, _):
        slot = jax.lax.rem(c, 2)

        @pl.when(c + 1 < nc)
        def _prefetch():
            dma(1 - slot, c + 1).start()

        dma(slot, c).wait()
        # Yc tile transposed: (vals_chunk^T Q) = (Q^T vals_chunk)^T  [bc, R]
        ycT = jnp.dot(vbuf[slot].T, q, preferred_element_type=acc)
        a = jnp.dot(ycT, h, preferred_element_type=acc)   # (Y_k^T H) tile
        cm = cm_ref[0, pl.ds(c * bc, bc)].astype(acc)
        out_ref[0, pl.ds(c * bc, bc), :] = a * w[None, :] * cm[:, None]
        return 0

    jax.lax.fori_loop(0, nc, step, 0)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def fused_mode2_compact(
    vals: jax.Array,
    Q: jax.Array,
    H: jax.Array,
    Wb: jax.Array,
    col_mask: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """vals [K,I,C], Q [K,I,R], H [R,R], Wb [K,R] (mask pre-folded),
    col_mask [K,C] -> A [K,C,R] = (Y_k^T H) * W(k,:) with Y_k = Q_k^T X_k
    recomputed tile-wise in VMEM — the projection never reaches HBM."""
    K, I, C = vals.shape
    R = Q.shape[-1]
    acc = accum_dtype(vals)
    if K == 0:
        return jnp.zeros((K, C, R), acc)
    bc = _pick_block_c(C, I, vals.dtype.itemsize, block_c)
    nc = pl.cdiv(C, bc)
    C_pad = nc * bc
    vals = _pad_c(vals, 2, C_pad)
    col_mask = _pad_c(col_mask, 1, C_pad)
    out = pl.pallas_call(
        functools.partial(_mode2_kernel, nc=nc, bc=bc, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # vals: manual DMA
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((R, R), lambda k: (0, 0)),
            pl.BlockSpec((1, R), lambda k: (k, 0)),
            pl.BlockSpec((1, C_pad), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, C_pad, R), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, C_pad, R), acc),
        scratch_shapes=[
            pltpu.VMEM((2, I, bc), vals.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(vals, Q, H, Wb, col_mask)
    return out[:, :C, :]


# ---------------------------------------------------------------------------
# F4: projection + Y_k V (third slab pass; feeds mode-3 and the fit)
# ---------------------------------------------------------------------------

def _ykv_kernel(vals_hbm, q_ref, vg_ref, out_ref, vbuf, sem,
                *, nc: int, bc: int, acc):
    k = pl.program_id(0)
    R = out_ref.shape[1]

    def dma(slot, c):
        return pltpu.make_async_copy(
            vals_hbm.at[k, :, pl.ds(c * bc, bc)], vbuf.at[slot], sem.at[slot])

    dma(0, 0).start()
    q = q_ref[0].astype(acc)                              # [I, R]

    def step(c, g):
        slot = jax.lax.rem(c, 2)

        @pl.when(c + 1 < nc)
        def _prefetch():
            dma(1 - slot, c + 1).start()

        dma(slot, c).wait()
        yc = jnp.dot(q.T, vbuf[slot].astype(acc),
                     preferred_element_type=acc)          # Yc tile [R, bc]
        vg_c = vg_ref[0, pl.ds(c * bc, bc), :].astype(acc)
        return g + jnp.dot(yc, vg_c, preferred_element_type=acc)

    out_ref[0] = jax.lax.fori_loop(0, nc, step, jnp.zeros((R, R), acc))


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def fused_ykv(
    vals: jax.Array,
    Q: jax.Array,
    Vg: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """vals [K,I,C], Q [K,I,R], Vg [K,C,R] -> G [K,R,R] = (Q_k^T X_k) V,
    the shared mode-3 / fit product, with the projection tile-local."""
    K, I, C = vals.shape
    R = Q.shape[-1]
    acc = accum_dtype(vals)
    if K == 0:
        return jnp.zeros((K, R, R), acc)
    bc = _pick_block_c(C, I, vals.dtype.itemsize, block_c)
    nc = pl.cdiv(C, bc)
    vals = _pad_c(vals, 2, nc * bc)
    Vg = _pad_c(Vg, 1, nc * bc)
    return pl.pallas_call(
        functools.partial(_ykv_kernel, nc=nc, bc=bc, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # vals: manual DMA
            pl.BlockSpec((1, I, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, nc * bc, R), lambda k: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, R), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R, R), acc),
        scratch_shapes=[
            pltpu.VMEM((2, I, bc), vals.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(vals, Q, Vg)
