"""Pallas TPU kernel — the shared Y_k V product.

Computes  YkV[k] = Y_k V  ([K, R, R]) from the compressed slices and gathered
V rows: one R x C @ C x R matmul per subject on the MXU, tiled over C with
the R x R partial product accumulated in the revisited output VMEM window.
This is the stage mode-1 reuse, mode-3 reuse, and the fit computation all
share — computing it once per bucket halves the dominant C-contraction cost
of the W-update + fit half of an ALS iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import accum_dtype

__all__ = ["ykv_pallas"]


def _kernel(yc_ref, vg_ref, out_ref, *, acc):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0] += jnp.dot(yc_ref[0], vg_ref[0], preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ykv_pallas(
    Yc: jax.Array,
    Vg: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Yc [K,R,C], Vg [K,C,R] -> YkV [K,R,R] (accum_dtype accumulation)."""
    K, R, C = Yc.shape
    acc = accum_dtype(Yc)
    if K == 0:
        return jnp.zeros((K, R, R), acc)
    bc = min(block_c, C)
    nc = pl.cdiv(C, bc)
    if C % bc:  # zero-pad partial tile (zero columns contribute nothing)
        pad = nc * bc - C
        Yc = jnp.pad(Yc, ((0, 0), (0, 0), (0, pad)))
        Vg = jnp.pad(Vg, ((0, 0), (0, pad), (0, 0)))
    grid = (K, nc)
    return pl.pallas_call(
        functools.partial(_kernel, acc=acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bc), lambda k, c: (k, 0, c)),
            pl.BlockSpec((1, bc, R), lambda k, c: (k, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, R), lambda k, c: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R, R), acc),
        interpret=interpret,
    )(Yc, Vg)
