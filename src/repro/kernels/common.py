"""Small helpers shared by the MTTKRP kernel wrappers."""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["fold_subject_mask", "accum_dtype", "compute_cast", "PRECISIONS"]

# compute-precision knob values (Parafac2Options.precision / --precision):
# "f32" streams operands as-is; "bf16"/"f16" stage the streamed values
# half-width (the MXU's full-rate input format) while every contraction
# still accumulates through accum_dtype below.
PRECISIONS = ("f32", "bf16", "f16")


def accum_dtype(x: Union[jax.Array, jnp.dtype, type, None]) -> jnp.dtype:
    """Accumulation dtype for a contraction over ``x``: f64 in -> f64 accum
    (the exact-algebra tests rely on it), bf16/f16 in -> f32 accum
    (half-precision partial sums lose mass over the subject/column axes),
    f32 and non-floats pass through. Accepts an array or a dtype.

    This is the single policy behind every ``preferred_element_type`` in the
    kernels and their jnp oracles — hardcoding ``jnp.float32`` there silently
    downgraded f64 runs to f32 accumulation.
    """
    dt = jnp.dtype(getattr(x, "dtype", x))
    if not jnp.issubdtype(dt, jnp.floating):
        return dt
    if jnp.finfo(dt).bits < 32:
        return jnp.dtype(jnp.float32)
    return dt


def compute_cast(x: Optional[jax.Array], precision: str = "f32") -> Optional[jax.Array]:
    """Stage a streamed operand at the requested compute precision.

    ``"f32"`` passes through unchanged (whatever dtype the caller staged —
    including f64). ``"bf16"`` / ``"f16"`` cast floating inputs half-width so
    the MXU runs at full rate; pair with ``accum_dtype`` so the products
    still accumulate in f32. None and non-float arrays pass through.
    """
    if x is None or precision == "f32" or precision is None:
        return x
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown compute precision {precision!r}; choose from {PRECISIONS}")
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float16
    if jnp.issubdtype(x.dtype, jnp.floating):
        return x.astype(dt)
    return x


def fold_subject_mask(Wb: jax.Array, subject_mask: Optional[jax.Array]) -> jax.Array:
    """Fold ``subject_mask`` [K] into the W rows [K, R]: every mode scales a
    subject's whole contribution by W(k,:), so masking W masks the subject
    exactly (the one place this identity is encoded)."""
    if subject_mask is None:
        return Wb
    return Wb * subject_mask[:, None].astype(Wb.dtype)
