"""Small helpers shared by the MTTKRP kernel wrappers."""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["fold_subject_mask"]


def fold_subject_mask(Wb: jax.Array, subject_mask: Optional[jax.Array]) -> jax.Array:
    """Fold ``subject_mask`` [K] into the W rows [K, R]: every mode scales a
    subject's whole contribution by W(k,:), so masking W masks the subject
    exactly (the one place this identity is encoded)."""
    if subject_mask is None:
        return Wb
    return Wb * subject_mask[:, None].astype(Wb.dtype)
