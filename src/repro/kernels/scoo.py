"""O(nnz) SCOO contractions: segment-sum references + Pallas TPU kernels.

The SCOO device format (repro.core.irregular.SparseBucket) stores each
subject's slice as sorted flat COO triplets padded to the bucket-wide N_pad:

  vals  f[Kb, N]   nonzero values (pad entries 0 — they vanish in every sum)
  rows  i32[Kb, N] local row index into the I_pad row space (pad: 0)
  lcols i32[Kb, N] local kept-column slot into the C_pad column space (pad: 0)

Every per-iteration contraction on this layout is a *gather + segment-sum*:
pick factor rows by index, scale by the nonzero values, and sum the
contributions that share a destination row/column. FLOPs and HBM traffic are
O(nnz * R) — independent of the densified I_pad * C_pad rectangle the CC
format pays for (docs/ARCHITECTURE.md, SCOO stage):

  xk_times_v   (X_k V)[i,:]   = sum_{n: rows[n]=i}  vals[n] * Vg[lcols[n], :]
  project      (Q^T X_k)[:,c] = sum_{n: lcols[n]=c} vals[n] * Q[rows[n], :]
  ykv          (Y_k V)[r,l]   = sum_n vals[n] * Q[rows[n], r] * Vg[lcols[n], l]
  mode2        A[c,:]         = sum_{n: lcols[n]=c} vals[n] * (Q H)[rows[n], :]

The jnp path exploits the *sorted* in "sorted flat COO": with precomputed
CSR/CSC-style segment boundaries (``row_ends`` for the row-major view;
``cperm``/``col_ends`` for the column-sorted view — host-side artifacts of
``bucketize``), a segment-sum is ``diff(cumsum(contrib)[ends])`` — pure
gathers and a prefix sum, no scatter at all (XLA scatter-add serializes on
CPU and is the difference between O(nnz) on paper and O(nnz) in wall-clock).
Pad entries carry value 0 and sit past every boundary, so they vanish from
every segment. Passing ``ends=None`` falls back to batched scatter-adds
(``.at[].add``) — the order-independent oracle the boundary path is tested
against. The Pallas variants (behind the same ``use_pallas`` / ``interpret``
switch as the CC kernels in :mod:`repro.kernels.ops`) tile the nnz axis and
run each gather/segment-sum as a one-hot matmul on the MXU — indices become
``iota == index`` masks, so the irregular memory access pattern turns into
dense [BN, C] / [BN, I] matmuls that Mosaic can lower, and the per-subject
true nonzero counts are *scalar-prefetched* so blocks past a subject's nnz
are skipped entirely.

Accumulation is f32 for sub-f32 inputs (half-precision segment-sums lose
mass; same policy as ``repro.core.spartan._f``), cast back to the input
dtype on the way out; f64 stays f64 so the algebra tests stay exact.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "xk_times_v", "project", "ykv_scoo", "mode1_scoo", "mode2_compact_scoo",
    "mode3_scoo", "xk_times_v_pallas", "project_pallas",
]


from repro.kernels.common import accum_dtype as _acc  # shared accumulation policy


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# jnp segment-sum path (sorted-boundary cumsum, scatter-add oracle fallback)
# ---------------------------------------------------------------------------

def _gather_n(M: jax.Array, idx: jax.Array) -> jax.Array:
    """Batched row gather: M [Kb, S, R], idx i32 [Kb, N] -> [Kb, N, R]."""
    return jnp.take_along_axis(M, idx[..., None], axis=1)


def segment_sum_sorted(contrib: jax.Array, ends: jax.Array) -> jax.Array:
    """Segment-sum of sorted contributions via prefix-sum differencing.

    contrib [Kb, N, R] sorted by destination segment; ends i32 [Kb, S] with
    ``ends[k, s]`` = index one past segment s's last entry (CSR-style row
    pointers, monotone, all <= true nnz so trailing pads never land in any
    segment) -> [Kb, S, R]. No scatter: cumsum + gather + diff.
    """
    csum = jnp.cumsum(contrib, axis=1)                    # [Kb, N, R]
    csum = jnp.pad(csum, ((0, 0), (1, 0), (0, 0)))        # prepend zero row
    e = jnp.take_along_axis(csum, ends[..., None], axis=1)  # [Kb, S, R]
    return jnp.diff(e, axis=1, prepend=jnp.zeros_like(e[:, :1]))


def _segsum(contrib, idx, ends, n_out: int):
    """Boundary path when ``ends`` is given, scatter-add oracle otherwise."""
    if ends is not None:
        return segment_sum_sorted(contrib, ends)
    Kb = contrib.shape[0]
    out = jnp.zeros((Kb, n_out, contrib.shape[-1]), contrib.dtype)
    return out.at[jnp.arange(Kb)[:, None], idx].add(contrib)


def xk_times_v(vals, rows, lcols, Vg, i_pad: int, *, row_ends=None,
               nnz_counts=None, use_pallas: bool = False,
               interpret: Optional[bool] = None):
    """X_k V from SCOO triplets: gather-from-Vg + segment-sum over rows.

    vals [Kb,N], rows/lcols i32 [Kb,N], Vg [Kb,C,R] (V rows for the bucket's
    kept columns, already masked) -> [Kb, I_pad, R]. ``row_ends``
    (i32 [Kb, I_pad], from bucketize) selects the scatter-free sorted path;
    ``nnz_counts`` (i32 [Kb], true per-subject counts) lets the Pallas
    variant skip all-padding nnz blocks.
    """
    if use_pallas:
        return xk_times_v_pallas(
            vals, rows, lcols, Vg, i_pad, nnz_counts=nnz_counts,
            interpret=_interpret() if interpret is None else interpret,
        ).astype(vals.dtype)
    acc = _acc(vals.dtype)
    g = _gather_n(Vg.astype(acc), lcols)                  # [Kb, N, R]
    contrib = g * vals.astype(acc)[..., None]
    return _segsum(contrib, rows, row_ends, i_pad).astype(vals.dtype)


def project(vals, rows, lcols, Q, c_pad: int, *, cperm=None, col_ends=None,
            nnz_counts=None, use_pallas: bool = False,
            interpret: Optional[bool] = None):
    """Y_k = Q_k^T X_k from SCOO triplets: gather-from-Q + segment-sum over
    kept columns -> compact [Kb, R, C_pad] (the CC ``Yc`` layout, bitwise
    column-compatible with the bucket's shared ``cols`` ids). ``cperm`` /
    ``col_ends`` (the column-sorted view from bucketize) select the
    scatter-free sorted path; ``nnz_counts`` feeds the Pallas block-skip."""
    if use_pallas:
        return project_pallas(
            vals, rows, lcols, Q, c_pad, nnz_counts=nnz_counts,
            interpret=_interpret() if interpret is None else interpret,
        ).astype(vals.dtype)
    acc = _acc(vals.dtype)
    if cperm is not None and col_ends is not None:
        rows = jnp.take_along_axis(rows, cperm, axis=1)
        vals_c = jnp.take_along_axis(vals, cperm, axis=1)
        idx = None
    else:
        vals_c, col_ends, idx = vals, None, lcols
    qg = _gather_n(Q.astype(acc), rows)                   # [Kb, N, R]
    contrib = qg * vals_c.astype(acc)[..., None]
    out = _segsum(contrib, idx, col_ends, c_pad)          # [Kb, C, R]
    return jnp.swapaxes(out, 1, 2).astype(vals.dtype)     # [Kb, R, C]


def ykv_scoo(vals, rows, lcols, Q, Vg):
    """Y_k V [Kb, R, R] natively from the triplets (never materializing Yc):
    sum_n vals[n] * Q[rows[n], :] (x) Vg[lcols[n], :] — O(nnz * R^2)."""
    acc = _acc(vals.dtype)
    qg = _gather_n(Q.astype(acc), rows)                   # [Kb, N, R]
    vg = _gather_n(Vg.astype(acc), lcols)                 # [Kb, N, R]
    return jnp.einsum("knr,knl->krl", qg * vals.astype(acc)[..., None], vg)


def mode1_scoo(vals, rows, lcols, Q, Vg, Wb, subject_mask):
    """Partial M1 [R, R] natively: the ykv outer-product sum, row-Hadamard
    with W(k,:), reduced over real subjects (matches spartan.mode1_bucket)."""
    YkV = ykv_scoo(vals, rows, lcols, Q, Vg)
    scaled = YkV * Wb.astype(YkV.dtype)[:, None, :]
    return jnp.einsum("krl,k->rl", scaled, subject_mask.astype(YkV.dtype))


def mode2_compact_scoo(vals, rows, lcols, Q, H, Wb, col_mask, subject_mask,
                       *, cperm=None, col_ends=None):
    """Compact mode-2 A [Kb, C, R] natively: A[k,c,:] = (Y_k(:,c)^T H) * W(k,:)
    = segment-sum over kept columns of vals[n] * (Q_k H)[rows[n], :], then the
    same W/col/subject masking as spartan.mode2_bucket_compact. ``cperm`` /
    ``col_ends`` select the scatter-free column-sorted path."""
    acc = _acc(vals.dtype)
    QH = jnp.einsum("kir,rl->kil", Q.astype(acc), H.astype(acc))
    if cperm is not None and col_ends is not None:
        rows = jnp.take_along_axis(rows, cperm, axis=1)
        vals = jnp.take_along_axis(vals, cperm, axis=1)
        idx = None
    else:
        col_ends, idx = None, lcols
    g = _gather_n(QH, rows)                               # [Kb, N, R]
    contrib = g * vals.astype(acc)[..., None]
    c_pad = col_mask.shape[-1]
    A = _segsum(contrib, idx, col_ends, c_pad)            # [Kb, C, R]
    A = A * Wb.astype(acc)[:, None, :]
    return A * (col_mask * subject_mask[:, None]).astype(acc)[..., None]


def mode3_scoo(vals, rows, lcols, Q, Vg, H, subject_mask):
    """Per-subject M3 rows [Kb, R] natively: coldot(H, Y_k V) with Y_k V from
    the triplet outer-product sum (matches spartan.mode3_bucket)."""
    YkV = ykv_scoo(vals, rows, lcols, Q, Vg)
    rows_out = jnp.einsum("rl,krl->kl", H.astype(YkV.dtype), YkV)
    return rows_out * subject_mask.astype(YkV.dtype)[:, None]


# ---------------------------------------------------------------------------
# Pallas TPU kernels: one-hot MXU gathers + scalar-prefetched nnz skipping
# ---------------------------------------------------------------------------
#
# Grid (Kb, nnz blocks of BN). Per step the kernel sees one subject's vals/
# rows/lcols block plus that subject's whole Vg (or Q) panel in VMEM, and
# accumulates into the revisited [I_pad, R] (or [C_pad, R]) output window.
# The gather `Vg[lcols]` is a one-hot matmul (lcols == iota) on the MXU —
# Mosaic has no per-element dynamic gather, but the segment-matrix trick
# turns it into a dense [BN, C] @ [C, R] product whose HBM traffic is still
# O(nnz + C*R) per subject. The per-subject true nonzero count is a
# scalar-prefetch operand: blocks entirely past it are skipped with pl.when
# (the subject-aligned padding guarantees they contribute nothing).

_BLOCK_N = 512


def _block_skip_counts(nnz_counts, vals) -> jax.Array:
    """Per-subject count for the scalar-prefetched block-skip guard. Without
    the bucket's true ``nnz_counts``, skip nothing (every entry counts):
    explicit zero-VALUED triplets are legal, so inferring counts from
    ``vals != 0`` would drop real entries that follow a stored zero."""
    if nnz_counts is not None:
        return nnz_counts.astype(jnp.int32)
    Kb, N = vals.shape
    return jnp.full((Kb,), N, jnp.int32)


def _xkv_kernel(nnz_ref, vals_ref, rows_ref, lcols_ref, vg_ref, out_ref,
                *, block_n: int, acc):
    k, b = pl.program_id(0), pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(b * block_n < nnz_ref[k])
    def _accum():
        vals = vals_ref[0].astype(acc)                    # [BN]
        lc = lcols_ref[0]                                 # [BN] i32
        r = rows_ref[0]                                   # [BN] i32
        C = vg_ref.shape[1]
        I = out_ref.shape[1]
        BN = vals.shape[0]
        onehot_c = (lc[:, None] ==
                    lax.broadcasted_iota(jnp.int32, (BN, C), 1))
        g = jnp.dot(onehot_c.astype(acc), vg_ref[0].astype(acc),
                    preferred_element_type=acc)           # [BN, R]
        contrib = g * vals[:, None]
        onehot_r = (r[:, None] ==
                    lax.broadcasted_iota(jnp.int32, (BN, I), 1))
        out_ref[0] += jnp.dot(onehot_r.astype(acc).T, contrib,
                              preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("i_pad", "block_n", "interpret"))
def xk_times_v_pallas(vals, rows, lcols, Vg, i_pad: int, *, nnz_counts=None,
                      block_n: int = _BLOCK_N, interpret: bool = False):
    """Pallas X_k V: [Kb,N] triplets + Vg [Kb,C,R] -> [Kb, I_pad, R] (f32)."""
    Kb, N = vals.shape
    R = Vg.shape[-1]
    acc = _acc(vals)
    if Kb == 0:
        return jnp.zeros((Kb, i_pad, R), acc)
    nnz = _block_skip_counts(nnz_counts, vals)
    bn = min(block_n, N)
    nb = pl.cdiv(N, bn)
    if N % bn:
        padn = nb * bn - N
        vals = jnp.pad(vals, ((0, 0), (0, padn)))
        rows = jnp.pad(rows, ((0, 0), (0, padn)))
        lcols = jnp.pad(lcols, ((0, 0), (0, padn)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Kb, nb),
        in_specs=[
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1,) + Vg.shape[1:], lambda k, b, nnz: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, i_pad, R), lambda k, b, nnz: (k, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_xkv_kernel, block_n=bn, acc=acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Kb, i_pad, R), acc),
        interpret=interpret,
    )(nnz, vals, rows, lcols, Vg)


def _project_kernel(nnz_ref, vals_ref, rows_ref, lcols_ref, q_ref, out_ref,
                    *, block_n: int, acc):
    k, b = pl.program_id(0), pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(b * block_n < nnz_ref[k])
    def _accum():
        vals = vals_ref[0].astype(acc)                    # [BN]
        lc = lcols_ref[0]
        r = rows_ref[0]
        I = q_ref.shape[1]
        C = out_ref.shape[2]
        BN = vals.shape[0]
        onehot_r = (r[:, None] ==
                    lax.broadcasted_iota(jnp.int32, (BN, I), 1))
        qg = jnp.dot(onehot_r.astype(acc), q_ref[0].astype(acc),
                     preferred_element_type=acc)          # [BN, R]
        contrib = qg * vals[:, None]                      # [BN, R]
        onehot_c = (lc[:, None] ==
                    lax.broadcasted_iota(jnp.int32, (BN, C), 1))
        # out [R, C] += contrib^T @ onehot_c
        out_ref[0] += jnp.dot(contrib.T, onehot_c.astype(acc),
                              preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("c_pad", "block_n", "interpret"))
def project_pallas(vals, rows, lcols, Q, c_pad: int, *, nnz_counts=None,
                   block_n: int = _BLOCK_N, interpret: bool = False):
    """Pallas Y_k = Q_k^T X_k: triplets + Q [Kb,I,R] -> [Kb, R, c_pad],
    accumulated in the shared accum dtype (f32 for sub-f64 inputs)."""
    Kb, N = vals.shape
    R = Q.shape[-1]
    acc = _acc(vals)
    if Kb == 0:
        return jnp.zeros((Kb, R, c_pad), acc)
    nnz = _block_skip_counts(nnz_counts, vals)
    bn = min(block_n, N)
    nb = pl.cdiv(N, bn)
    if N % bn:
        padn = nb * bn - N
        vals = jnp.pad(vals, ((0, 0), (0, padn)))
        rows = jnp.pad(rows, ((0, 0), (0, padn)))
        lcols = jnp.pad(lcols, ((0, 0), (0, padn)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Kb, nb),
        in_specs=[
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1, bn), lambda k, b, nnz: (k, b)),
            pl.BlockSpec((1,) + Q.shape[1:], lambda k, b, nnz: (k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, c_pad), lambda k, b, nnz: (k, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_project_kernel, block_n=bn, acc=acc),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Kb, R, c_pad), acc),
        interpret=interpret,
    )(nnz, vals, rows, lcols, Q)
