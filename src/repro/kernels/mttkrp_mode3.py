"""Pallas TPU kernel — SPARTan mode-3 MTTKRP.

Computes  M3(k,:) = coldot(H, Y_k V): the R x R product Y_k V is formed on the
MXU (tiled over C), then contracted column-wise against H on the VPU. One
output row per subject. The C-tiling accumulates the R x R partial product in
a VMEM scratch buffer; the coldot runs on the final tile.

Two entry points mirror mode-1: :func:`mode3_pallas` (full gather+matmul) and
:func:`mode3_reuse_pallas` (Y_k V pre-computed — only the coldot remains).
``subject_mask`` zeroes the output rows of padded subjects, matching
``spartan.mode3_bucket``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import accum_dtype

__all__ = ["mode3_pallas", "mode3_reuse_pallas"]


def _mask_rows(out: jax.Array, subject_mask: Optional[jax.Array]) -> jax.Array:
    if subject_mask is None:
        return out
    return out * subject_mask[:, None].astype(out.dtype)


def _kernel(yc_ref, vg_ref, h_ref, out_ref, acc_ref, *, nc: int, acc):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(yc_ref[0], vg_ref[0], preferred_element_type=acc)

    @pl.when(c == nc - 1)
    def _fin():
        out_ref[0] = jnp.sum(h_ref[...].astype(acc) * acc_ref[...], axis=0)  # coldot


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def mode3_pallas(
    Yc: jax.Array,
    Vg: jax.Array,
    H: jax.Array,
    subject_mask: Optional[jax.Array] = None,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Yc [K,R,C], Vg [K,C,R], H [R,R] -> [K,R]. ``subject_mask`` [K] zeroes
    rows of padded subjects."""
    K, R, C = Yc.shape
    acc = accum_dtype(Yc)
    if K == 0:
        return jnp.zeros((K, R), acc)
    bc = min(block_c, C)
    nc = pl.cdiv(C, bc)
    if C % bc:  # zero-pad partial tile
        pad = nc * bc - C
        Yc = jnp.pad(Yc, ((0, 0), (0, 0), (0, pad)))
        Vg = jnp.pad(Vg, ((0, 0), (0, pad), (0, 0)))
    grid = (K, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, nc=nc, acc=acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bc), lambda k, c: (k, 0, c)),
            pl.BlockSpec((1, bc, R), lambda k, c: (k, c, 0)),
            pl.BlockSpec((R, R), lambda k, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R), lambda k, c: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R), acc),
        scratch_shapes=[pltpu.VMEM((R, R), acc)],
        interpret=interpret,
    )(Yc, Vg, H)
    return _mask_rows(out, subject_mask)


def _reuse_kernel(ykv_ref, h_ref, out_ref, *, acc):
    ykv = ykv_ref[0].astype(acc)
    out_ref[0] = jnp.sum(h_ref[...].astype(acc) * ykv, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mode3_reuse_pallas(
    YkV: jax.Array,
    H: jax.Array,
    subject_mask: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """YkV [K,R,R] (= Y_k V, cached), H [R,R] -> [K,R]: per-subject coldot
    only — the matmul was paid upstream."""
    K, R, _ = YkV.shape
    acc = accum_dtype(YkV)
    if K == 0:
        return jnp.zeros((K, R), acc)
    out = pl.pallas_call(
        functools.partial(_reuse_kernel, acc=acc),
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, R, R), lambda k: (k, 0, 0)),
            pl.BlockSpec((R, R), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, R), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R), acc),
        interpret=interpret,
    )(YkV, H)
    return _mask_rows(out, subject_mask)
