"""Pallas TPU kernel — SPARTan mode-2 MTTKRP, compact compute stage.

Computes  A[k] = (Y_k^T H) * W(k,:)  for the kept columns only (paper Fig. 3);
the J-space scatter-add is a separate memory-bound stage handled by XLA
(`spartan.mode2_scatter`). The C x R result per subject stays in VMEM;
C is tiled for large kept-column counts. H (R x R) is small and replicated to
every grid step (the paper's "size imbalance" property).

``col_mask`` [K,C] zeroes rows for padded columns inside the kernel (so the
downstream scatter of slot-0 column ids stays harmless); ``subject_mask`` [K]
is folded into W(k,:) — both make the kernel drop-in equal to
``spartan.mode2_bucket_compact``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import accum_dtype, fold_subject_mask

__all__ = ["mode2_compact_pallas"]


def _kernel(yc_ref, h_ref, wb_ref, cm_ref, out_ref, *, acc):
    # yc [1, R, bc]; h [R, R]; wb [1, R]; cm [1, bc]; out [1, bc, R]
    ytH = jnp.dot(yc_ref[0].T, h_ref[...], preferred_element_type=acc)
    out_ref[0] = ytH * wb_ref[0].astype(acc)[None, :] * cm_ref[0].astype(acc)[:, None]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def mode2_compact_pallas(
    Yc: jax.Array,
    H: jax.Array,
    Wb: jax.Array,
    col_mask: Optional[jax.Array] = None,
    subject_mask: Optional[jax.Array] = None,
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Yc [K,R,C], H [R,R], Wb [K,R] -> A [K,C,R]. Optional ``col_mask``
    [K,C] / ``subject_mask`` [K] zero padded columns / subjects."""
    K, R, C = Yc.shape
    acc = accum_dtype(Yc)
    if K == 0:
        return jnp.zeros((K, C, R), acc)
    Wb = fold_subject_mask(Wb, subject_mask)
    if col_mask is None:
        col_mask = jnp.ones((K, C), jnp.float32)
    bc = min(block_c, C)
    nc = pl.cdiv(C, bc)
    C_pad = nc * bc
    if C % bc:
        Yc = jnp.pad(Yc, ((0, 0), (0, 0), (0, C_pad - C)))
        col_mask = jnp.pad(col_mask, ((0, 0), (0, C_pad - C)))
    grid = (K, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, acc=acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bc), lambda k, c: (k, 0, c)),
            pl.BlockSpec((R, R), lambda k, c: (0, 0)),
            pl.BlockSpec((1, R), lambda k, c: (k, 0)),
            pl.BlockSpec((1, bc), lambda k, c: (k, c)),
        ],
        out_specs=pl.BlockSpec((1, bc, R), lambda k, c: (k, c, 0)),
        out_shape=jax.ShapeDtypeStruct((K, C_pad, R), acc),
        interpret=interpret,
    )(Yc, H, Wb, col_mask)
    return out[:, :C, :]
