"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python/XLA-CPU for correctness validation. On TPU they
compile to Mosaic. ``use_pallas=False`` falls back to the jnp oracle (ref.py),
which is also what the pure-JAX SPARTan path uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.mttkrp_mode1 import mode1_pallas
from repro.kernels.mttkrp_mode2 import mode2_compact_pallas
from repro.kernels.mttkrp_mode3 import mode3_pallas
from repro.kernels.gather_matmul import gather_matmul_pallas

__all__ = ["mttkrp_mode1", "mttkrp_mode2_compact", "mttkrp_mode3", "gather_matmul"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mttkrp_mode1(Yc, Vg, Wb, *, use_pallas: bool = True, block_c: int = 512):
    if not use_pallas:
        return ref.mode1_ref(Yc, Vg, Wb)
    return mode1_pallas(Yc, Vg, Wb, block_c=block_c, interpret=_interpret())


def mttkrp_mode2_compact(Yc, H, Wb, *, use_pallas: bool = True, block_c: int = 512):
    if not use_pallas:
        return ref.mode2_compact_ref(Yc, H, Wb)
    return mode2_compact_pallas(Yc, H, Wb, block_c=block_c, interpret=_interpret())


def mttkrp_mode3(Yc, Vg, H, *, use_pallas: bool = True, block_c: int = 512):
    if not use_pallas:
        return ref.mode3_ref(Yc, Vg, H)
    return mode3_pallas(Yc, Vg, H, block_c=block_c, interpret=_interpret())


def gather_matmul(vals, blk_ids, V, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.gather_matmul_ref(vals, blk_ids, V)
    return gather_matmul_pallas(vals, blk_ids, V, interpret=_interpret())
