"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python/XLA-CPU for correctness validation. On TPU they
compile to Mosaic. ``use_pallas=False`` falls back to the jnp oracle (ref.py),
which is also what the pure-JAX SPARTan path uses.

These wrappers carry the full SPARTan bucket semantics (``subject_mask`` /
``col_mask`` zeroing of padding, the ``YkV`` pre-computed reuse path) so the
:class:`repro.core.backend.PallasBackend` can treat them as drop-in equals of
the ``core/spartan.py`` math.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import fold_subject_mask
from repro.kernels.mttkrp_mode1 import mode1_pallas, mode1_reuse_pallas
from repro.kernels.mttkrp_mode2 import mode2_compact_pallas
from repro.kernels.mttkrp_mode3 import mode3_pallas, mode3_reuse_pallas
from repro.kernels.ykv import ykv_pallas
from repro.kernels.gather_matmul import gather_matmul_pallas

__all__ = ["ykv", "mttkrp_mode1", "mttkrp_mode2_compact", "mttkrp_mode3",
           "gather_matmul"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ykv(Yc: jax.Array, Vg: jax.Array, *, use_pallas: bool = True,
        block_c: int = 512) -> jax.Array:
    """The shared Y_k V product [K,R,R] (mode-1/3 reuse + fit)."""
    if not use_pallas:
        return ref.ykv_ref(Yc, Vg)
    return ykv_pallas(Yc, Vg, block_c=block_c, interpret=_interpret())


def mttkrp_mode1(
    Yc: Optional[jax.Array],
    Vg: Optional[jax.Array],
    Wb: jax.Array,
    *,
    subject_mask: Optional[jax.Array] = None,
    YkV: Optional[jax.Array] = None,
    use_pallas: bool = True,
    block_c: int = 512,
) -> jax.Array:
    """M1 partial [R,R]. With ``YkV`` given ([K,R,R] = Y_k V cached), Yc/Vg
    may be None and only the Hadamard + subject reduction runs."""
    if YkV is not None:
        if not use_pallas:
            return ref.mode1_reuse_ref(YkV, fold_subject_mask(Wb, subject_mask))
        return mode1_reuse_pallas(YkV, Wb, subject_mask, interpret=_interpret())
    if not use_pallas:
        return ref.mode1_ref(Yc, Vg, fold_subject_mask(Wb, subject_mask))
    return mode1_pallas(Yc, Vg, Wb, subject_mask, block_c=block_c,
                        interpret=_interpret())


def mttkrp_mode2_compact(
    Yc: jax.Array,
    H: jax.Array,
    Wb: jax.Array,
    *,
    col_mask: Optional[jax.Array] = None,
    subject_mask: Optional[jax.Array] = None,
    use_pallas: bool = True,
    block_c: int = 512,
) -> jax.Array:
    """Compact per-column A [K,C,R]; rows for masked columns/subjects are 0."""
    if not use_pallas:
        A = ref.mode2_compact_ref(Yc, H, fold_subject_mask(Wb, subject_mask))
        if col_mask is not None:
            A = A * col_mask[..., None].astype(A.dtype)
        return A
    return mode2_compact_pallas(Yc, H, Wb, col_mask, subject_mask,
                                block_c=block_c, interpret=_interpret())


def mttkrp_mode3(
    Yc: Optional[jax.Array],
    Vg: Optional[jax.Array],
    H: jax.Array,
    *,
    subject_mask: Optional[jax.Array] = None,
    YkV: Optional[jax.Array] = None,
    use_pallas: bool = True,
    block_c: int = 512,
) -> jax.Array:
    """M3 rows [K,R]. With ``YkV`` given, Yc/Vg may be None (coldot only)."""
    if YkV is not None:
        if not use_pallas:
            out = ref.mode3_reuse_ref(YkV, H)
        else:
            return mode3_reuse_pallas(YkV, H, subject_mask,
                                      interpret=_interpret())
    elif not use_pallas:
        out = ref.mode3_ref(Yc, Vg, H)
    else:
        return mode3_pallas(Yc, Vg, H, subject_mask, block_c=block_c,
                            interpret=_interpret())
    if subject_mask is not None:
        out = out * subject_mask[:, None].astype(out.dtype)
    return out


def gather_matmul(vals, blk_ids, V, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.gather_matmul_ref(vals, blk_ids, V)
    return gather_matmul_pallas(vals, blk_ids, V, interpret=_interpret())
