"""mamba2-780m [ssm] — SSD / state-space duality, attention-free
(arXiv:2405.21060). d_inner = 2*d_model = 3072, 48 heads of 64."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,          # SSD heads (d_inner / ssm_head_dim)
    n_kv_heads=1,
    d_ff=0,              # attention-free: no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
)
