"""llama4-maverick-400b-a17b [moe] — 128e top-1, shared expert, interleaved
MoE layers, early fusion (hf:meta-llama/Llama-4 family)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    shared_expert=True,
    block_pattern=("attn_mlp", "attn_moe"),   # interleaved dense/MoE
)
