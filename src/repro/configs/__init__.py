"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, reduced

_MODULES: Dict[str, str] = {
    "whisper-medium": "repro.configs.whisper_medium",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "qwen3-0.6b": "repro.configs.qwen3_0p6b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(name: str) -> ArchConfig:
    try:
        mod = _MODULES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return importlib.import_module(mod).CONFIG


def applicable_shapes(cfg: ArchConfig) -> List[str]:
    """Which assignment shapes run for this arch (skips noted in DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")   # SSM / hybrid-local only
    return out


__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "reduced",
    "get_config",
    "list_archs",
    "applicable_shapes",
]
