"""minicpm-2b [dense] — llama-like arch, trained with WSD (arXiv:2404.06395)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
)
