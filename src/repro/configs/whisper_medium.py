"""whisper-medium [audio] — enc-dec, conv frontend stub (arXiv:2212.04356)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    is_encdec=True,
    encoder_layers=24,
    encoder_seq=1500,          # 30s of audio at 50 frames/s (conv stub output)
    frontend="audio_stub",
    block_pattern=("attn_cross_mlp",),
)
