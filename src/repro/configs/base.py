"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` in its own module under
``repro/configs``; the registry in ``__init__`` resolves ``--arch <id>``.
Shapes are global-batch x sequence cells from the assignment; ``kind``
distinguishes train vs. inference-prefill vs. decode lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads
    qk_norm: bool = False
    act: str = "swiglu"              # swiglu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False      # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    # --- hybrid (recurrentgemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    local_window: int = 0                 # sliding-window size for local attn
    rglru_width: int = 0                  # RG-LRU recurrence width (d_model scale)
    # --- enc-dec (whisper) ---
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame count (conv frontend stub)
    # --- modality stub ---
    frontend: str = ""               # "" | "audio_stub" | "patch_stub"
    n_prefix_embeds: int = 0         # vlm: patch embeddings prepended to text
    # --- numerics / training ---
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # "nothing" | "save_block_outputs"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (SSM / hybrid-local-attn)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (reported in the roofline table)."""
        d, f, vocab = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.n_experts:
            mlp_total = self.n_experts * mlp + d * self.n_experts
            if self.shared_expert:
                mlp_total += mlp
        else:
            mlp_total = mlp
        per_layer = attn + mlp_total + 2 * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per_layer = (d * (2 * d_in + 2 * self.ssm_state + nheads)
                         + d_in * self.conv_width + d_in * d + 2 * d)
        if self.family == "hybrid" and self.block_pattern:
            w = self.rglru_width or d
            rg = d * w * 3 + w * d + 2 * w  # gates + projections (approx)
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.block_pattern[i % len(self.block_pattern)] == "attn")
            n_rg = self.n_layers - n_attn
            per_layer = 0  # handled below
            total_layers = n_attn * (attn + mlp + 2 * d) + n_rg * (rg + mlp + 2 * d)
            emb = vocab * d * (1 if self.tie_embeddings else 2)
            return total_layers + emb
        n_layers = self.n_layers + self.encoder_layers
        emb = vocab * d * (1 if self.tie_embeddings else 2)
        total = n_layers * per_layer + emb
        if self.is_encdec:
            total += self.n_layers * attn  # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f if self.act == "swiglu" else 2 * d * f
        inactive = (self.n_experts - self.experts_per_token) * mlp
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        dtype="float32",
        remat=False,
    )
    if cfg.n_experts:
        base.update(n_experts=4, experts_per_token=min(2, cfg.experts_per_token))
    if cfg.family == "ssm":
        base.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        base.update(local_window=16, rglru_width=64, n_layers=3)
    if cfg.is_encdec:
        base.update(encoder_layers=2, encoder_seq=16)
    if cfg.n_prefix_embeds:
        base.update(n_prefix_embeds=4)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
