"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2 (arXiv:2402.19427)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn_local"),
    local_window=2048,
    rglru_width=4096,
)
