"""pixtral-12b [vlm] — pixtral-ViT frontend stub + mistral-nemo backbone
(hf:mistralai/Pixtral-12B-2409). Patch embeddings arrive precomputed."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    frontend="patch_stub",
    n_prefix_embeds=256,       # one 1024x1024 image at 64px patches (stub)
)
