"""stablelm-3b [dense] (hf:stabilityai/stablelm family)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
)
