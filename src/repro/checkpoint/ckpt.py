"""Checkpointing: atomic, step-stamped, elastic-restorable.

Layout:  <dir>/step_000123/
             meta.json           step, flat key list, extra state (data iter)
             <flat-key>.npy      one array per param/opt leaf (globally
                                 unsharded values — any future mesh can load)
         <dir>/step_000123.tmp-* staging dir, atomically renamed on success

Elasticity: arrays are stored as *global* (fully addressable) values; on load
they are re-sharded by whatever sharding rules the new mesh applies. A resume
on 64 chips of a checkpoint written on 512 therefore needs no conversion.
Partial/corrupt checkpoints are never visible (atomic rename), and
``latest_step`` skips damaged directories (crash-during-save tolerance).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps"]

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree: Any, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest `keep`."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    staging = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=directory)
    flat = _flatten(tree)
    dtypes = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = arr.dtype.name
        if arr.dtype.name == "bfloat16":   # numpy can't serialize bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(staging, f"{abs(hash(key)) % 10**12:012d}.npy"), arr)
    meta = {
        "step": step,
        "keys": {key: f"{abs(hash(key)) % 10**12:012d}.npy" for key in flat},
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(staging, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(staging, final)
    # prune old checkpoints
    steps = all_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            path = os.path.join(directory, name, "meta.json")
            if os.path.exists(path):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of `tree_like`; apply `shardings` if given
    (elastic re-shard happens here via jax.device_put)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    base = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(base, "meta.json")) as f:
        meta = json.load(f)
    flat_keys = _flatten(tree_like)
    leaves_by_key = {}
    for key in flat_keys:
        fname = meta["keys"].get(key)
        if fname is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(base, fname))
        if meta.get("dtypes", {}).get(key) == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        leaves_by_key[key] = arr

    flat_shard = _flatten(shardings) if shardings is not None else None

    def rebuild(path, leaf):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = leaves_by_key[key]
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None:
            arr = arr.astype(want_dtype)
        if flat_shard is not None:
            return jax.device_put(arr, flat_shard[key])
        return jax.numpy.asarray(arr)

    restored = jax.tree_util.tree_map_with_path(rebuild, tree_like)
    return restored, int(meta["step"]), meta.get("extra", {})
