from repro.checkpoint.ckpt import all_steps, latest_step, restore, save

__all__ = ["all_steps", "latest_step", "restore", "save"]
