"""Paper Figure 5 — time/iteration vs target rank on CHOA-shaped and
MovieLens-shaped data (geometry-preserving shrinks), SPARTan vs baseline."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core.parafac2 import als_step
from repro.core.baseline import baseline_als_step
from repro.data import choa_like, movielens_like
from benchmarks.common import emit, time_call


def run(dataset: str, data, ranks=(5, 10, 20, 40), iters: int = 3) -> None:
    bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
    for R in ranks:
        opts = Parafac2Options(rank=R, constraints={"v": "nonneg", "w": "nonneg"})
        state = init_state(bt, opts, seed=0)
        sp = jax.jit(lambda s: als_step(bt, s, opts))
        bl = jax.jit(lambda s: baseline_als_step(bt, s, opts))
        t_sp, _ = time_call(sp, state, iters=iters)
        t_bl, _ = time_call(bl, state, iters=iters)
        emit(f"fig5/{dataset}/spartan/R{R}", t_sp, f"speedup={t_bl/t_sp:.2f}x")
        emit(f"fig5/{dataset}/baseline/R{R}", t_bl, "")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--choa-scale", type=float, default=0.002)
    ap.add_argument("--ml-scale", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    run("choa", choa_like(scale=args.choa_scale, seed=0), iters=args.iters)
    run("movielens", movielens_like(scale=args.ml_scale, seed=0), iters=args.iters)


if __name__ == "__main__":
    main()
