"""Streaming-append benchmark: per-append serving latency + throughput.

The batch benchmarks (`als_e2e.py`) time whole decompositions; the serving
workload (`launch/stream.py`) is different — it pays one padded, jitted
``update_subjects`` dispatch per request batch against FIXED factors, and
what matters is the tail of the per-append latency distribution plus the
sustained append throughput. This benchmark streams a synthetic append
workload through a warm-started :class:`repro.launch.stream.StreamService`
and reports, per device format:

  ``append/<fmt>``: ``p50_us_per_call`` / ``p99_us_per_call`` (per-append
  wall latency; GATED lower-better by `benchmarks/compare.py`, which keys on
  the ``us_per_call`` suffix), ``subjects_per_s`` (sustained appends per
  second of dispatch wall time, informational), and the append/batch counts.
  ``refit/<fmt>``: wall seconds of one full drift refit over the accumulated
  union (informational — refits are rare by design).

The service's sticky batch geometry is pre-grown to cover the whole stream
(a production deployment provisions its padded rectangle up front), so after
the first compiled batch every dispatch reuses one jit entry; the first
``--warmup-batches`` batches are excluded from the latency distribution.

  PYTHONPATH=src python -m benchmarks.stream_bench --warm 24 --appends 48 \
      --rank 4 --batch-slots 8 --formats cc,scoo --json BENCH_stream.json

The JSON artifact is a `compare.py` namespace (``stream``); CI gates it
against the checked-in baseline and appends it to BENCH_trajectory.jsonl.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options
from repro.sparse import random_irregular
from repro.launch.stream import StreamService, synthetic_stream, validate_payload
from benchmarks.common import calibrate, emit


def _bench_format(fmt: str, args) -> dict:
    data = random_irregular(
        n_subjects=args.warm + args.appends, n_cols=args.cols,
        max_rows=args.max_rows, avg_nnz_per_subject=args.avg_nnz,
        seed=args.seed)
    warm, payloads = synthetic_stream(
        data, warm_frac=args.warm / (args.warm + args.appends),
        touch_frac=args.touch_frac, seed=args.seed)
    opts = Parafac2Options(rank=args.rank, dtype=jnp.float32)
    svc, _ = StreamService.warm_start(
        warm, opts, iters=args.warm_iters, seed=args.seed,
        batch_slots=args.batch_slots, drift_threshold=np.inf, format=fmt)

    # provision the padded rectangle for the WHOLE stream up front so every
    # post-warmup batch reuses the same compiled dispatch
    blocks = [validate_payload(p, warm.n_cols, len(svc.subjects))[1]
              for p in payloads]
    svc._batch_geometry(blocks)

    for p in payloads:
        svc.submit(p)
    svc.flush()

    skip = min(args.warmup_batches, max(svc.n_batches - 1, 0))
    lat = np.asarray(svc.batch_latencies[skip:], dtype=np.float64)
    n, bs = svc.n_appends, args.batch_slots
    sizes = np.asarray([bs] * (n // bs) + ([n % bs] if n % bs else []))
    # per-append latency = the batch's wall time (each request rides one
    # dispatch); the distribution is over appends, weighted by batch size
    per_append = np.repeat(lat, sizes[skip:][: lat.size])
    busy = float(lat.sum())
    row = {
        "p50_us_per_call": float(np.percentile(per_append, 50) * 1e6),
        "p99_us_per_call": float(np.percentile(per_append, 99) * 1e6),
        "subjects_per_s": (per_append.size / busy) if busy > 0 else 0.0,
        "appends": int(per_append.size),
        "batches": int(lat.size),
        "compiled_geometries": svc.stats()["compiled_geometries"],
    }

    t0 = time.perf_counter()
    svc.refit(mode="warm")
    refit_s = time.perf_counter() - t0
    return row, {"refit_seconds": refit_s,
                 "n_subjects": len(svc.subjects),
                 "stream_fit": svc.stream_fit}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--warm", type=int, default=24,
                    help="subjects in the warm-start population")
    ap.add_argument("--appends", type=int, default=48,
                    help="append requests to stream")
    ap.add_argument("--cols", type=int, default=512)
    ap.add_argument("--max-rows", type=int, default=64)
    ap.add_argument("--avg-nnz", type=float, default=96.0)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--warm-iters", type=int, default=10)
    ap.add_argument("--batch-slots", type=int, default=8)
    ap.add_argument("--touch-frac", type=float, default=0.25)
    ap.add_argument("--formats", default="cc,scoo",
                    help="comma list from cc,scoo,auto")
    ap.add_argument("--warmup-batches", type=int, default=2,
                    help="leading batches excluded from the latency "
                         "distribution (compile + cache warmup)")
    ap.add_argument("--json", default="",
                    help="write the compare.py namespace to this JSON file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    results = {"config": {
        "warm": args.warm, "appends": args.appends, "cols": args.cols,
        "rank": args.rank, "batch_slots": args.batch_slots,
        "platform": jax.default_backend(), "calib_seconds": calibrate(),
    }}
    for fmt in [s.strip() for s in args.formats.split(",") if s.strip()]:
        row, refit = _bench_format(fmt, args)
        results[f"append/{fmt}"] = row
        results[f"refit/{fmt}"] = refit
        emit(f"stream/append/{fmt}/p50", row["p50_us_per_call"] / 1e6,
             f"p99={row['p99_us_per_call']:.0f}us "
             f"{row['subjects_per_s']:.1f}subj/s")
        emit(f"stream/refit/{fmt}", refit["refit_seconds"],
             f"K={refit['n_subjects']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
