"""Shared benchmark plumbing: timing + CSV emission.

CPU timings here measure the ALGORITHMIC gap (SPARTan vs. materialized-KRP
baseline) on geometry-preserving shrinks of the paper's datasets; the TPU
story is carried by the dry-run roofline terms (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["time_call", "emit", "calibrate"]


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kwargs) -> Tuple[float, object]:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def emit(name: str, seconds: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def calibrate(iters: int = 5) -> float:
    """Median seconds of a fixed reference workload (jitted matmul chain).

    Every benchmark stamps this into its JSON (``config.calib_seconds``) so
    the perf gate (`benchmarks/compare.py`) can normalize timings across
    machines of different speed: a run is compared as ``time / calib``
    against the checked-in baseline's ``time / calib`` — a CI runner that is
    uniformly 2× slower than the baseline machine does not trip the gate,
    a real regression in one case still does.
    """
    x = jnp.ones((256, 256), jnp.float32)

    @jax.jit
    def ref(x):
        for _ in range(8):
            x = jnp.tanh(x @ x) * 0.5
        return x

    t, _ = time_call(ref, x, warmup=2, iters=iters)
    return t
