"""Shared benchmark plumbing: timing + CSV emission.

CPU timings here measure the ALGORITHMIC gap (SPARTan vs. materialized-KRP
baseline) on geometry-preserving shrinks of the paper's datasets; the TPU
story is carried by the dry-run roofline terms (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax

__all__ = ["time_call", "emit"]


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kwargs) -> Tuple[float, object]:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def emit(name: str, seconds: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
