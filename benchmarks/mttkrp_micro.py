"""Kernel-level micro-benchmark: per-mode SPARTan MTTKRP vs materialized-KRP
baseline on identical inputs (the paper's core computational claim).

``--backends jnp,pallas`` times every requested MTTKRP backend side by side
in one invocation (rows ``mttkrp/<mode>/<backend>``), each against the shared
dense baseline; ``--json PATH`` additionally writes the timings as a JSON
artifact (the CI perf trajectory, BENCH_mttkrp.json).
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bucketize
from repro.core.backend import get_backend
from repro.core.baseline import baseline_mode1, baseline_mode2, baseline_mode3, dense_y
from repro.sparse import random_irregular
from benchmarks.common import calibrate, emit, time_call


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--subjects", type=int, default=2000)
    ap.add_argument("--cols", type=int, default=2000)
    ap.add_argument("--rank", type=int, default=40)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--backends", default="jnp,pallas",
                    help="comma list of MTTKRP backends to time side by side")
    ap.add_argument("--json", default="",
                    help="write per-mode/backend timings to this JSON file")
    args = ap.parse_args(argv)

    # geometry mirrors the paper's sparse regime: few active columns (c_k)
    # out of many variables J — that is where the reformulation wins.
    rng = np.random.default_rng(0)
    data = random_irregular(n_subjects=args.subjects, n_cols=args.cols,
                            max_rows=30, avg_nnz_per_subject=60, seed=5)
    K, J, R = data.n_subjects, data.n_cols, args.rank
    bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, R)), jnp.float32)
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)),
                                 jnp.float32)) for b in bt.buckets]

    # factors are traced ARGUMENTS (otherwise XLA constant-folds the whole
    # computation and the timing is meaningless); bucket data is closed over
    # identically for every method.
    Y = jax.jit(lambda: dense_y(bt.buckets, Ycs, J, K))()
    base_fns = {
        "mode1": (jax.jit(lambda V, W: baseline_mode1(Y, V, W)), (V, W)),
        "mode2": (jax.jit(lambda H, W: baseline_mode2(Y, H, W)), (H, W)),
        "mode3": (jax.jit(lambda H, V: baseline_mode3(Y, H, V)), (H, V)),
    }
    base = {}
    for name, (fn, fargs) in base_fns.items():
        base[name] = time_call(fn, *fargs, iters=args.iters)

    results = {"config": {"subjects": K, "cols": J, "rank": R,
                          "platform": jax.default_backend(),
                          "calib_seconds": calibrate()}}
    for bname in [s.strip() for s in args.backends.split(",") if s.strip()]:
        be = get_backend(bname)
        sp_fns = {
            "mode1": (jax.jit(lambda V, W: be.mttkrp_mode1(bt.buckets, Ycs, V, W)),
                      (V, W)),
            "mode2": (jax.jit(lambda H, W: be.mttkrp_mode2(bt.buckets, Ycs, H, W, J)),
                      (H, W)),
            "mode3": (jax.jit(lambda H, V: be.mttkrp_mode3(bt.buckets, Ycs, V, H, K)),
                      (H, V)),
        }
        for name, (fn, fargs) in sp_fns.items():
            t_sp, a = time_call(fn, *fargs, iters=args.iters)
            t_bl, b = base[name]
            err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-30))
            emit(f"mttkrp/{name}/{bname}", t_sp,
                 f"speedup={t_bl/t_sp:.2f}x relerr={err:.2e}")
            results[f"{name}/{bname}"] = {
                "us_per_call": t_sp * 1e6, "speedup_vs_baseline": t_bl / t_sp,
                "relerr": err}
    for name, (t_bl, _) in base.items():
        emit(f"mttkrp/{name}/baseline", t_bl, "")
        results[f"{name}/baseline"] = {"us_per_call": t_bl * 1e6}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
