"""Kernel-level micro-benchmark: per-mode SPARTan MTTKRP vs materialized-KRP
baseline on identical inputs (the paper's core computational claim)."""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bucketize
from repro.core import spartan
from repro.core.baseline import baseline_mode1, baseline_mode2, baseline_mode3, dense_y
from repro.sparse import random_irregular
from benchmarks.common import emit, time_call


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--subjects", type=int, default=2000)
    ap.add_argument("--cols", type=int, default=2000)
    ap.add_argument("--rank", type=int, default=40)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args(argv)

    # geometry mirrors the paper's sparse regime: few active columns (c_k)
    # out of many variables J — that is where the reformulation wins.
    rng = np.random.default_rng(0)
    data = random_irregular(n_subjects=args.subjects, n_cols=args.cols,
                            max_rows=30, avg_nnz_per_subject=60, seed=5)
    K, J, R = data.n_subjects, data.n_cols, args.rank
    bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, R)), jnp.float32)
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)),
                                 jnp.float32)) for b in bt.buckets]

    # factors are traced ARGUMENTS (otherwise XLA constant-folds the whole
    # computation and the timing is meaningless); bucket data is closed over
    # identically for both methods.
    @jax.jit
    def spartan_m1(V, W):
        return sum(spartan.mode1_bucket(Yc, b.gather_v(V),
                                        jnp.take(W, b.subject_ids, 0),
                                        b.subject_mask)
                   for b, Yc in zip(bt.buckets, Ycs))

    @jax.jit
    def spartan_m2(H, W):
        return spartan.mttkrp_mode2(
            [(Yc, jnp.take(W, b.subject_ids, 0), b.cols, b.col_mask,
              b.subject_mask) for b, Yc in zip(bt.buckets, Ycs)], H, J)

    @jax.jit
    def spartan_m3(H, V):
        return spartan.mttkrp_mode3(
            [(Yc, b.gather_v(V), b.subject_ids, b.subject_mask)
             for b, Yc in zip(bt.buckets, Ycs)], H, K)

    Y = jax.jit(lambda: dense_y(bt.buckets, Ycs, J, K))()
    base_m1 = jax.jit(lambda V, W: baseline_mode1(Y, V, W))
    base_m2 = jax.jit(lambda H, W: baseline_mode2(Y, H, W))
    base_m3 = jax.jit(lambda H, V: baseline_mode3(Y, H, V))

    for name, sp_fn, bl_fn, fargs in (
            ("mode1", spartan_m1, base_m1, (V, W)),
            ("mode2", spartan_m2, base_m2, (H, W)),
            ("mode3", spartan_m3, base_m3, (H, V))):
        t_sp, a = time_call(sp_fn, *fargs, iters=args.iters)
        t_bl, b = time_call(bl_fn, *fargs, iters=args.iters)
        err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-30))
        emit(f"mttkrp/{name}/spartan", t_sp,
             f"speedup={t_bl/t_sp:.2f}x relerr={err:.2e}")
        emit(f"mttkrp/{name}/baseline", t_bl, "")


if __name__ == "__main__":
    main()
