"""Kernel-level micro-benchmark: per-mode SPARTan MTTKRP vs materialized-KRP
baseline on identical inputs (the paper's core computational claim).

``--backends jnp,pallas`` times every requested MTTKRP backend side by side
in one invocation (rows ``mttkrp/<mode>/<backend>``), each against the shared
dense baseline; ``--formats cc,scoo`` adds the device-format axis (rows for
non-CC formats get a ``/<fmt>`` suffix; SCOO stages run the O(nnz)
segment-sum route of :mod:`repro.kernels.scoo` through the bucket-level
backend API). The format axis also times the two formation stages the
whole-iteration cost is dominated by on sparse data — ``xkv`` (X_k V) and
``project`` (Y_k = Q_k^T X_k) — which the mode-level rows never see.
``--json PATH`` additionally writes the timings as a JSON artifact (the CI
perf trajectory, BENCH_mttkrp.json), including a ``dispatches_per_iter``
block per backend — the bucket-stage dispatch count one full ALS iteration
costs (staged backends: 5/bucket; the fused megakernel route: 4/bucket, the
exact-parity fusion floor — see repro.kernels.fused).
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, als_step, bucketize, init_state
from repro.core.backend import dispatch_tally, get_backend
from repro.core.baseline import baseline_mode1, baseline_mode2, baseline_mode3, dense_y
from repro.sparse import random_irregular
from benchmarks.common import calibrate, emit, time_call


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--subjects", type=int, default=2000)
    ap.add_argument("--cols", type=int, default=2000)
    ap.add_argument("--rank", type=int, default=40)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--backends", default="jnp,pallas",
                    help="comma list of MTTKRP backends to time side by side "
                         "(jnp,pallas,scoo,fused,auto)")
    ap.add_argument("--fused", action="store_true",
                    help="shorthand: append 'fused' to the backends axis")
    ap.add_argument("--formats", default="cc",
                    help="comma list of device formats (cc,scoo); non-CC "
                         "rows get a /<fmt> suffix")
    ap.add_argument("--json", default="",
                    help="write per-mode/backend timings to this JSON file")
    args = ap.parse_args(argv)
    backends = [s.strip() for s in args.backends.split(",") if s.strip()]
    if args.fused and "fused" not in backends:
        backends.append("fused")

    # geometry mirrors the paper's sparse regime: few active columns (c_k)
    # out of many variables J — that is where the reformulation wins.
    rng = np.random.default_rng(0)
    data = random_irregular(n_subjects=args.subjects, n_cols=args.cols,
                            max_rows=30, avg_nnz_per_subject=60, seed=5)
    K, J, R = data.n_subjects, data.n_cols, args.rank
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, R)), jnp.float32)

    # the CC bucketing defines the shared geometry; the SCOO bucketing reuses
    # the identical plan so every format sees the same buckets and the same
    # random Q (and therefore bitwise-identical Yc up to accumulation order)
    from repro.sparse import plan_buckets
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=4)
    bts = {}
    for fmt in [s.strip() for s in args.formats.split(",") if s.strip()]:
        bts[fmt] = bucketize(data, dtype=jnp.float32, plan=plan,
                             formats=[fmt] * plan.n_buckets)
    bt0 = next(iter(bts.values()))
    Qs = [jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)), jnp.float32)
          for b in bt0.buckets]
    Ycs = [b.project(Q) for b, Q in zip(bts.get("cc", bt0).buckets, Qs)]

    # factors are traced ARGUMENTS (otherwise XLA constant-folds the whole
    # computation and the timing is meaningless); bucket data is closed over
    # identically for every method.
    Y = jax.jit(lambda: dense_y(bt0.buckets, Ycs, J, K))()
    base_fns = {
        "mode1": (jax.jit(lambda V, W: baseline_mode1(Y, V, W)), (V, W)),
        "mode2": (jax.jit(lambda H, W: baseline_mode2(Y, H, W)), (H, W)),
        "mode3": (jax.jit(lambda H, V: baseline_mode3(Y, H, V)), (H, V)),
    }
    base = {}
    for name, (fn, fargs) in base_fns.items():
        base[name] = time_call(fn, *fargs, iters=args.iters)

    results = {"config": {"subjects": K, "cols": J, "rank": R,
                          "nnz": data.nnz,
                          "platform": jax.default_backend(),
                          "calib_seconds": calibrate()}}
    for fmt, bt in bts.items():
        sfx = "" if fmt == "cc" else f"/{fmt}"
        for bname in backends:
            be = get_backend(bname)
            buckets = bt.buckets
            # per-bucket projected representations (untimed, like Ycs): the
            # dense route materializes Yc, the scoo backend carries Q
            projs = [be.project_bucket(b, Q) for b, Q in zip(buckets, Qs)]

            def run_mode1(V, W):
                return sum(
                    be.mode1_bucket(b, p, jnp.take(W, b.subject_ids, 0), V)
                    for b, p in zip(buckets, projs))

            def run_mode2(H, W):
                M2 = jnp.zeros((J, R), H.dtype)
                for b, p in zip(buckets, projs):
                    A = be.mode2_bucket(b, p, H, jnp.take(W, b.subject_ids, 0))
                    M2 = M2 + be.mode2_scatter(A, b.cols, J).astype(M2.dtype)
                return M2

            def run_mode3(H, V):
                M3 = jnp.zeros((K, R), H.dtype)
                for b, p in zip(buckets, projs):
                    rows = be.mode3_bucket(b, p, H, V)
                    M3 = M3.at[b.subject_ids].add(rows.astype(M3.dtype))
                return M3

            sp_fns = {
                "mode1": (jax.jit(run_mode1), (V, W)),
                "mode2": (jax.jit(run_mode2), (H, W)),
                "mode3": (jax.jit(run_mode3), (H, V)),
            }
            for name, (fn, fargs) in sp_fns.items():
                t_sp, a = time_call(fn, *fargs, iters=args.iters)
                t_bl, b = base[name]
                err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-30))
                emit(f"mttkrp/{name}/{bname}{sfx}", t_sp,
                     f"speedup={t_bl/t_sp:.2f}x relerr={err:.2e}")
                results[f"{name}/{bname}{sfx}"] = {
                    "us_per_call": t_sp * 1e6, "speedup_vs_baseline": t_bl / t_sp,
                    "relerr": err}

            # formation stages (X_k V, Q^T X_k): the O(nnz)-vs-O(I*C) gap
            # lives here, not in the compact mode contractions
            def run_xkv(V):
                return [be.xkv_bucket(b, V) for b in buckets]

            def run_project(H):
                # H is a stand-in traced arg to defeat constant folding
                return [be.project_bucket(b, Q * H[0, 0]) for b, Q in
                        zip(buckets, Qs)]

            t_x, _ = time_call(jax.jit(run_xkv), V, iters=args.iters)
            emit(f"mttkrp/xkv/{bname}{sfx}", t_x, "")
            results[f"xkv/{bname}{sfx}"] = {"us_per_call": t_x * 1e6}
            # the scoo backend's project_bucket on SCOO buckets — and the
            # fused backend's on EVERY bucket — is Q pass-through BY DESIGN
            # (Yc is never materialized; the cost moves into the fused/
            # triplet contractions timed above) — a timing row for it would
            # be a meaningless ~0
            if not (bname == "fused"
                    or (fmt == "scoo" and bname in ("scoo", "auto"))):
                t_p, _ = time_call(jax.jit(run_project), H, iters=args.iters)
                emit(f"mttkrp/project/{bname}{sfx}", t_p, "")
                results[f"project/{bname}{sfx}"] = {"us_per_call": t_p * 1e6}
    for name, (t_bl, _) in base.items():
        emit(f"mttkrp/{name}/baseline", t_bl, "")
        results[f"{name}/baseline"] = {"us_per_call": t_bl * 1e6}

    # bucket-stage dispatch count per full ALS iteration (ticks fire at
    # trace time, so eval_shape counts one als_step without running it):
    # staged = 5/bucket, fused = 4/bucket (the exact-parity fusion floor)
    bt_cc = bts.get("cc", bt0)
    for bname in backends:
        opts = Parafac2Options(rank=R, dtype=jnp.float32, backend=bname)
        s0 = init_state(bt_cc, opts, seed=0)
        with dispatch_tally() as tally:
            jax.eval_shape(lambda s: als_step(bt_cc, s, opts), s0)
        per_iter = int(sum(tally.values()))
        per_bucket = per_iter / max(len(bt_cc.buckets), 1)
        emit(f"mttkrp/dispatches_per_iter/{bname}", 0.0,
             f"total={per_iter} per_bucket={per_bucket:.1f}")
        results[f"dispatches_per_iter/{bname}"] = {
            "total": per_iter, "per_bucket": per_bucket,
            "by_stage": dict(tally)}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
