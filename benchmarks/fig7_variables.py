"""Paper Figure 7 — MovieLens: time/iteration vs number of variables J, fixed
rank R in {10, 40}. J is varied by keeping the most popular J columns."""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core.parafac2 import als_step
from repro.core.baseline import baseline_als_step
from repro.data import movielens_like
from repro.sparse.coo import IrregularCOO, SubjectCOO
from benchmarks.common import emit, time_call


def restrict_columns(data: IrregularCOO, J_keep: int) -> IrregularCOO:
    """Keep the J_keep most frequent columns, remap ids, drop empty rows."""
    counts = np.zeros(data.n_cols, np.int64)
    for s in data.subjects:
        np.add.at(counts, s.cols, 1)
    keep = np.argsort(-counts)[:J_keep]
    remap = -np.ones(data.n_cols, np.int64)
    remap[keep] = np.arange(J_keep)
    subs = []
    for s in data.subjects:
        m = remap[s.cols] >= 0
        if not m.any():
            continue
        rows, cols, vals = s.rows[m], remap[s.cols[m]].astype(np.int32), s.vals[m]
        # re-pack rows (paper: all-zero rows are filtered)
        uniq, rr = np.unique(rows, return_inverse=True)
        subs.append(SubjectCOO(rows=rr.astype(np.int32), cols=cols, vals=vals,
                               n_rows=uniq.size, n_cols=J_keep))
    return IrregularCOO(subjects=subs, n_cols=J_keep)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--j-levels", type=int, nargs="*",
                    default=[2_000, 5_000, 10_000, 26_096])
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    full = movielens_like(scale=args.scale, seed=0)
    for J in args.j_levels:
        data = restrict_columns(full, min(J, full.n_cols))
        bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
        for R in (10, 40):
            opts = Parafac2Options(rank=R, constraints={"v": "nonneg", "w": "nonneg"})
            state = init_state(bt, opts, seed=0)
            sp = jax.jit(lambda s: als_step(bt, s, opts))
            bl = jax.jit(lambda s: baseline_als_step(bt, s, opts))
            t_sp, _ = time_call(sp, state, iters=args.iters)
            t_bl, _ = time_call(bl, state, iters=args.iters)
            emit(f"fig7/movielens/spartan/J{data.n_cols}/R{R}", t_sp,
                 f"speedup={t_bl/t_sp:.2f}x")
            emit(f"fig7/movielens/baseline/J{data.n_cols}/R{R}", t_bl, "")


if __name__ == "__main__":
    main()
