"""Paper Figure 6 — CHOA: time/iteration vs number of subjects K, fixed rank
R in {10, 40}."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core.parafac2 import als_step
from repro.core.baseline import baseline_als_step
from repro.data import choa_like
from benchmarks.common import emit, time_call


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", type=float, nargs="*",
                    default=[0.0005, 0.001, 0.002, 0.004])
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    for scale in args.scales:
        data = choa_like(scale=scale, seed=0)
        bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
        for R in (10, 40):
            opts = Parafac2Options(rank=R, constraints={"v": "nonneg", "w": "nonneg"})
            state = init_state(bt, opts, seed=0)
            sp = jax.jit(lambda s: als_step(bt, s, opts))
            bl = jax.jit(lambda s: baseline_als_step(bt, s, opts))
            t_sp, _ = time_call(sp, state, iters=args.iters)
            t_bl, _ = time_call(bl, state, iters=args.iters)
            emit(f"fig6/choa/spartan/K{data.n_subjects}/R{R}", t_sp,
                 f"speedup={t_bl/t_sp:.2f}x")
            emit(f"fig6/choa/baseline/K{data.n_subjects}/R{R}", t_bl, "")


if __name__ == "__main__":
    main()
