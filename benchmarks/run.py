"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Scales are small by default so
the full suite runs in minutes on CPU; pass --full for larger instances.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse

from benchmarks import fig5_rank, fig6_subjects, fig7_variables, mttkrp_micro, table1_synthetic


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger problem sizes")
    ap.add_argument("--only", default="", help="comma list: table1,fig5,fig6,fig7,micro")
    ap.add_argument("--backends", default="jnp,pallas",
                    help="comma list of MTTKRP backends for the micro rows "
                         "(jnp,pallas side by side by default)")
    ap.add_argument("--bench-json", default="",
                    help="write the micro per-mode/backend timings to this "
                         "JSON file (CI artifact)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    if only is None or "micro" in only:
        micro_args = ["--subjects", "4000" if args.full else "1000",
                      "--iters", "3", "--backends", args.backends]
        if args.bench_json:
            micro_args += ["--json", args.bench_json]
        mttkrp_micro.main(micro_args)
    if only is None or "table1" in only:
        table1_synthetic.main(["--scale", "0.004" if args.full else "0.001"])
    if only is None or "fig5" in only:
        fig5_rank.main(["--choa-scale", "0.004" if args.full else "0.001",
                        "--ml-scale", "0.02" if args.full else "0.005"])
    if only is None or "fig6" in only:
        fig6_subjects.main([] if args.full else
                           ["--scales", "0.0005", "0.001", "0.002"])
    if only is None or "fig7" in only:
        fig7_variables.main(["--scale", "0.02" if args.full else "0.005"])


if __name__ == "__main__":
    main()
