"""Render the §Roofline table from the dry-run JSON (results/dryrun.json).

PARAFAC2 cells appear alongside the LM cells; a cell lowered against the
SCOO format (``dryrun.py --parafac2 --format scoo``) carries the O(nnz)
useful-flops model — its MODEL/HLO column is the sparse path's roofline,
counting only padded triplets instead of the densified CC rectangles — and
renders with a ``/scoo`` shape tag. Cells lowered through a non-default
backend/precision (``--backend fused``, ``--precision bf16``) render with
``/fused`` / ``@bf16`` tags and fill the AI columns: ``AI(hlo)`` is measured
flops per HLO byte accessed, ``AI(model)`` the precision-aware streamed-slab
model (bf16/f16 slabs move 2 bytes per cell, f32 moves 4; the fused route
drops the Yc round-trip entirely — see launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import json
import os

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def render(path: str, mesh: str = "pod16x16", markdown: bool = True) -> str:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, r in sorted(results.items()):
        if key.startswith("_") or r.get("mesh") != mesh:
            continue
        if "t_compute" not in r:
            continue
        rows.append(r)
    hdr = ("| arch | shape | t_compute | t_memory(live) | t_memory(hlo-ub) | "
           "t_collective | bottleneck | GiB/dev | fits 16G | MODEL/HLO flops | "
           "roofline frac | AI(hlo) | AI(model) |")
    sep = "|" + "---|" * 13
    lines = [hdr, sep]
    for r in rows:
        shape = r["shape"]
        if r.get("format") and r["format"] != "cc":
            shape = f"{shape}/{r['format']}"
        if r.get("backend") and r["backend"] != "jnp":
            shape = f"{shape}/{r['backend']}"
        if r.get("precision") and r["precision"] != "f32":
            shape = f"{shape}@{r['precision']}"

        def ai(key):
            return f"{r[key]:.1f}" if r.get(key) else "-"

        lines.append(
            f"| {r['arch']} | {shape} | {fmt_t(r.get('t_compute'))} | "
            f"{fmt_t(r.get('t_memory'))} | {fmt_t(r.get('t_memory_hlo'))} | "
            f"{fmt_t(r.get('t_collective'))} | {r.get('bottleneck','-')[2:]} | "
            f"{r.get('bytes_per_device',0)/2**30:.2f} | "
            f"{'Y' if r.get('fits_hbm_16g') else 'N'} | "
            f"{r.get('useful_fraction',0):.2f} | "
            f"{r.get('roofline_fraction_compute',0):.2f} | "
            f"{ai('arithmetic_intensity')} | "
            f"{ai('model_arithmetic_intensity')} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=os.path.normpath(DEFAULT))
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args(argv)
    print(render(args.path, args.mesh))


if __name__ == "__main__":
    main()
