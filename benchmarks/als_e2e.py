"""End-to-end ALS benchmark: whole decompositions, engine × backend ×
format grid.

DPar2 (PAPERS.md) argues whole-decomposition time is the metric that matters —
the MTTKRP micro benchmark (`mttkrp_micro.py`) cannot see the per-iteration
host dispatch + `float(state.fit)` sync the host loop pays, which at small
ranks IS the wall-clock floor. This benchmark times `iters` ALS iterations
through each execution engine (host | scan | mesh — repro.core.engine),
backend (jnp | pallas), device data format (cc | scoo | auto —
repro.core.irregular; SCOO is the O(nnz) sparse path, and the low-density
``synthsparse`` dataset is the regime where CC's densified rectangles burn
~100x the FLOPs) and constraint route (none | nonneg | nonneg_admm |
smooth — repro.core.constraints; COPA's claim is that AO-ADMM constraints
ride the same MTTKRP core at negligible extra cost, and this axis measures
exactly that) on geometry-preserving shrinks of the paper's datasets
(`choa_like` / `movielens_like`), reporting steady-state seconds/iteration
(compile excluded; the compiled callables are built once, then timed), a
whole-run wall time, and ``peak_bytes`` — the compiled als_step's
argument+temp device allocation, the metric where SCOO's win is
density-proportional.

  PYTHONPATH=src python -m benchmarks.als_e2e --datasets synthsparse \
      --rank 5 --iters 20 --engines host,scan --formats cc,scoo \
      --constraints nonneg --json BENCH_als.json

Rows: ``als/<dataset>/<engine>/<backend>/<constraint>`` with a ``/scoo`` or
``/auto`` suffix for non-CC formats (CC rows keep the historical unsuffixed
names so the checked-in baseline stays comparable) and the canonical
compress spec as a suffix (``/rsvd:8:4:1``) for compressed runs
(``--compress none,rsvd:10:8:1`` — the DPar2-style
randomized compression stage of repro.core.compress: compression is timed
once as ``compress_seconds``, the grid times the CORE ALS, and
``speedup_vs_uncompressed_per_iter`` / ``fit_gap_vs_uncompressed`` record
the steady-state win and the accuracy cost vs the same uncompressed
configuration). ``--xl-probe`` runs the
"larger instance" demonstration: a geometry whose densified CC buffer alone
exceeds host+device memory, decomposed under SCOO and recorded with the CC
buffer size it avoided. The JSON artifact is the CI perf trajectory
(BENCH_als.json); `benchmarks/compare.py` gates it against the checked-in
baseline.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core import engine as als_engine
from repro.core.compress import parse_preprocess_spec
from repro.core.parafac2 import als_step
from repro.data import choa_like, movielens_like
from repro.sparse import random_irregular
from benchmarks.common import calibrate, emit, time_call

# the benchmark's constraint axis: name -> per-mode specs
CONSTRAINT_CASES = {
    "none": {"v": "none", "w": "none"},
    "nonneg": {"v": "nonneg", "w": "nonneg"},            # the paper's default
    "nonneg_admm": {"v": "nonneg_admm", "w": "nonneg_admm"},
    "l1": {"v": "nonneg+l1:0.1", "w": "nonneg"},
    "smooth": {"v": "nonneg", "w": "smooth:0.1"},
}


def _load(name: str, scale: float, seed: int):
    if name == "choa":
        return choa_like(scale=scale, seed=seed)
    if name == "movielens":
        return movielens_like(scale=scale, seed=seed)
    if name == "synthsparse":
        # EHR-like low intra-slice density (≤1% of the kept-column
        # rectangle): many observation rows, each touching a handful of the
        # kept columns — the regime the SCOO format exists for. K scales
        # like choa so --scale works uniformly.
        return random_irregular(
            n_subjects=max(64, int(256_000 * scale)), n_cols=4096,
            max_rows=256, avg_nnz_per_subject=256, seed=seed)
    raise ValueError(name)


def _peak_bytes(bt, opts) -> int:
    """Compiled als_step device allocation (arguments + temporaries) with the
    data passed as a runtime argument — counts the format's resident buffers
    plus the step's scratch, the number that decides whether a geometry fits."""
    state0 = init_state(bt, opts, seed=0)
    compiled = jax.jit(
        lambda d, s: als_step(d, s, opts)).lower(bt, state0).compile()
    mem = compiled.memory_analysis()
    return int((getattr(mem, "argument_size_in_bytes", 0) or 0)
               + (getattr(mem, "temp_size_in_bytes", 0) or 0))


def _make_runner(bt, opts, iters: int):
    """A zero-arg callable running `iters` ALS iterations the way the
    engine's fitting loop would, from a fixed init state, returning the final
    fit. Compiled callables are built ONCE here so timing excludes compile;
    donation is off so the init state survives repeated timed runs."""
    state0 = init_state(bt, opts, seed=0)

    if opts.engine == "host":
        step = jax.jit(lambda s: als_step(bt, s, opts))

        def run():
            s = state0
            f = float("nan")
            for _ in range(iters):
                s = step(s)
                f = float(s.fit)   # the host loop's per-iteration device sync
            return f

        return run

    # scan/mesh: ceil(iters / check_every) chunk dispatches, one sync each
    lengths = []
    left = iters
    while left > 0:
        n = min(opts.check_every or iters, left)
        lengths.append(n)
        left -= n
    chunks = {n: als_engine.make_als_chunk(bt, opts, n, donate=False)
              for n in set(lengths)}

    def run():
        s = state0
        f = float("nan")
        for n in lengths:
            s, fits = chunks[n](s)
            f = float(np.asarray(fits)[-1])
        return f

    return run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="choa,movielens")
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--rank", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--engines", default="host,scan",
                    help="comma list from host,scan,mesh")
    ap.add_argument("--backends", default="jnp",
                    help="comma list from jnp,pallas,scoo,fused,auto")
    ap.add_argument("--fused-namespace", action="store_true",
                    help="additionally run the compact als_fused grid: "
                         "pallas/f32 vs fused/f32 vs fused/bf16 on the first "
                         "dataset, host engine, interleaved repeats — rows "
                         "als_fused/<ds>/<backend>/<precision> with the gated "
                         "speedup_vs_pallas ratio")
    ap.add_argument("--formats", default="cc",
                    help="comma list from cc,scoo,auto (device data format; "
                         "cc rows keep the historical unsuffixed names)")
    ap.add_argument("--constraints", default="nonneg",
                    help=f"comma list from {','.join(CONSTRAINT_CASES)}")
    ap.add_argument("--compress", default="none",
                    help="comma list of repro.core.compress specs (e.g. "
                         "'none,rsvd:10:8:1'): non-identity specs compress "
                         "once (timed separately as compress_seconds), then "
                         "the grid times the CORE ALS; rows get a "
                         "'/<preprocess>' suffix and a gated "
                         "speedup_vs_uncompressed_per_iter ratio")
    ap.add_argument("--supervised-namespace", action="store_true",
                    help="additionally run the als_supervised grid: the bare "
                         "chunked scan loop vs a faultless supervised_fit "
                         "(repro.dist.supervisor) on the first dataset, "
                         "interleaved repeats — rows als_supervised/<ds>/bare "
                         "and /supervised with the paired "
                         "overhead_vs_bare_per_iter ratio")
    ap.add_argument("--overhead-gate", type=float, default=0.0,
                    help="with --supervised-namespace: fail (exit 1) if the "
                         "median paired supervised/bare s/iter ratio exceeds "
                         "this (e.g. 1.05 = supervisor overhead must stay "
                         "within 5%%); 0 disables the gate")
    ap.add_argument("--xl-probe", action="store_true",
                    help="run the 'larger instance' demo: a geometry whose "
                         "densified CC buffer exceeds memory, fit under SCOO "
                         "(records the avoided CC bytes; slow — not for CI)")
    ap.add_argument("--check-every", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repetitions per case (median reported)")
    ap.add_argument("--json", default="",
                    help="write per-case timings to this JSON file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    engines = [s.strip() for s in args.engines.split(",") if s.strip()]
    backends = [s.strip() for s in args.backends.split(",") if s.strip()]
    constraints = [s.strip() for s in args.constraints.split(",") if s.strip()]
    for c in constraints:
        if c not in CONSTRAINT_CASES:
            raise SystemExit(f"unknown constraint case {c!r}; choose from "
                             f"{', '.join(CONSTRAINT_CASES)}")
    compress_cases = [s.strip() for s in args.compress.split(",") if s.strip()]
    # parse eagerly (raises ValueError listing registered preprocessors) and
    # run identity first so the vs-uncompressed ratios always have their ref
    compress_cases.sort(key=lambda c: not parse_preprocess_spec(c).identity)
    results = {"config": {
        "scale": args.scale, "rank": args.rank, "iters": args.iters,
        "check_every": args.check_every, "platform": jax.default_backend(),
        "calib_seconds": calibrate(),
    }}

    formats = [s.strip() for s in args.formats.split(",") if s.strip()]
    # cc must be measured before the other formats so their vs-cc ratios
    # (the gated headline metrics) exist regardless of the flag order
    formats.sort(key=lambda f: f != "cc")
    for ds in [s.strip() for s in args.datasets.split(",") if s.strip()]:
        data = _load(ds, args.scale, args.seed)
        align = len(jax.devices()) if "mesh" in engines else 1
        for fmt in formats:
            bt = bucketize(data, max_buckets=4, dtype=jnp.float32,
                           subject_align=align, format=fmt)
            # CC rows keep the historical unsuffixed names; other formats
            # append "/<fmt>" so the baseline comparison stays stable
            suffix = "" if fmt == "cc" else f"/{fmt}"
            host_per_iter = {}
            cc_per_iter = {}
            peak_cache = {}
            comp_cache = {}
            uncompressed_ref = {}
            for engine in engines:
                for backend in backends:
                    for cname in constraints:
                        # two passes over the compress axis: build + warm
                        # every case's runner first, then interleave the
                        # timed repeats round-robin. The uncompressed and
                        # compressed runs land in the SAME noise window, so
                        # the gated speedup_vs_uncompressed ratio is robust
                        # to machine-load drift between measurement windows
                        # (sequential timing puts minutes between the pair).
                        prepped = []
                        for cspec in compress_cases:
                            pp = parse_preprocess_spec(cspec)
                            # the grid always times the (core) ALS itself:
                            # compression is a one-shot preprocessing stage,
                            # timed separately as compress_seconds
                            opts = Parafac2Options(
                                rank=args.rank,
                                constraints=CONSTRAINT_CASES[cname],
                                backend=backend, engine=engine,
                                check_every=args.check_every)
                            if pp.identity:
                                run_bt, compress_s, csuffix = bt, 0.0, ""
                            else:
                                if (backend, pp.spec) not in comp_cache:
                                    t0 = time.perf_counter()
                                    comp = pp.apply(bt, opts, seed=args.seed)
                                    jax.block_until_ready(
                                        jax.tree_util.tree_leaves(comp.data))
                                    comp_cache[(backend, pp.spec)] = (
                                        comp, time.perf_counter() - t0)
                                comp, compress_s = comp_cache[(backend, pp.spec)]
                                # the full canonical spec keeps two sketches
                                # of the same preprocessor (rsvd:8:4:1 vs
                                # rsvd:6:2:1) on distinct result keys
                                run_bt, csuffix = comp.data, f"/{pp.spec}"
                            pkey = (backend, cname, pp.spec)
                            if pkey not in peak_cache:
                                peak_cache[pkey] = _peak_bytes(run_bt, opts)
                            run = _make_runner(run_bt, opts, args.iters)
                            final_fit = float("nan")
                            for _ in range(2):  # compile + warm
                                final_fit = run()
                            prepped.append({
                                "pp": pp, "compress_s": compress_s,
                                "csuffix": csuffix, "peak": peak_cache[pkey],
                                "run": run, "final_fit": final_fit,
                                "times": []})
                        for _ in range(args.repeats):
                            for case in prepped:
                                t0 = time.perf_counter()
                                case["final_fit"] = case["run"]()
                                case["times"].append(
                                    time.perf_counter() - t0)
                        for case in prepped:
                            pp, csuffix = case["pp"], case["csuffix"]
                            compress_s, peak = case["compress_s"], case["peak"]
                            final_fit = case["final_fit"]
                            ts = sorted(case["times"])
                            seconds = ts[len(ts) // 2]
                            per_iter = seconds / args.iters
                            rel = ""
                            if engine == "host":
                                host_per_iter[(backend, cname, pp.spec)] = per_iter
                            elif (backend, cname, pp.spec) in host_per_iter:
                                speedup = (host_per_iter[(backend, cname, pp.spec)]
                                           / per_iter)
                                rel = f"speedup_vs_host={speedup:.2f}x"
                            emit(f"als/{ds}/{engine}/{backend}/{cname}"
                                 f"{suffix}{csuffix}",
                                 per_iter,
                                 f"fit={final_fit:.4f} peak={peak/2**20:.1f}MiB "
                                 f"{rel}".strip())
                            rec = {"seconds_per_iter": per_iter,
                                   "seconds_total": seconds,
                                   "iters": args.iters, "final_fit": final_fit,
                                   "peak_bytes": peak,
                                   "n_subjects": data.n_subjects,
                                   "nnz": data.nnz}
                            if rel:
                                rec["speedup_vs_host_per_iter"] = speedup
                            key = (engine, backend, cname)
                            if pp.identity:
                                uncompressed_ref[key] = (per_iter, final_fit)
                                if fmt == "cc":
                                    cc_per_iter[key] = per_iter
                                    results.setdefault("_cc_ref", {})[
                                        f"{ds}/{engine}/{backend}/{cname}"] = {
                                            "seconds_per_iter": per_iter,
                                            "peak_bytes": peak}
                                else:
                                    ref = results.get("_cc_ref", {}).get(
                                        f"{ds}/{engine}/{backend}/{cname}")
                                    if ref:
                                        rec["speedup_vs_cc_per_iter"] = (
                                            ref["seconds_per_iter"] / per_iter)
                                        rec["peak_bytes_vs_cc"] = (
                                            ref["peak_bytes"] / max(peak, 1))
                            else:
                                rec["compress_spec"] = pp.spec
                                rec["compress_seconds"] = compress_s
                                if key in uncompressed_ref:
                                    ref_s, ref_fit = uncompressed_ref[key]
                                    # the gated headline: steady-state core
                                    # s/iter vs the uncompressed same-config
                                    # run; fit_gap is informational
                                    rec["speedup_vs_uncompressed_per_iter"] = (
                                        ref_s / per_iter)
                                    rec["fit_gap_vs_uncompressed"] = (
                                        ref_fit - final_fit)
                            results[f"{ds}/{engine}/{backend}/{cname}"
                                    f"{suffix}{csuffix}"] = rec

    if args.fused_namespace:
        results.update(_fused_cases(args))

    overhead = None
    if args.supervised_namespace:
        rows, overhead = _supervised_cases(args)
        results.update(rows)

    if args.xl_probe:
        results["xl"] = _xl_probe(args)

    # _cc_ref was scaffolding for the vs-cc ratios, not a gated namespace
    results.pop("_cc_ref", None)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)

    if args.overhead_gate and overhead is not None and overhead > args.overhead_gate:
        raise SystemExit(
            f"FAIL: supervisor overhead {overhead:.3f}x exceeds the "
            f"--overhead-gate {args.overhead_gate:.2f}x budget")
    return results


def _fused_cases(args) -> dict:
    """The ``als_fused`` namespace: the fused megakernel route and bf16
    compute, each timed against the staged pallas backend on identical data
    (host engine, the paper's nonneg default). Warm-up first, then the timed
    repeats interleave round-robin so every ratio compares runs from the same
    noise window. On CPU the fused rows run the interpret-mode DMA emulation
    — the recorded speedup_vs_pallas is then a correctness-trajectory metric,
    not a perf claim (the TPU number is the real one)."""
    ds = [s.strip() for s in args.datasets.split(",") if s.strip()][0]
    data = _load(ds, args.scale, args.seed)
    bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
    cases = [("pallas", "f32"), ("fused", "f32"), ("fused", "bf16")]
    prepped = []
    for backend, precision in cases:
        opts = Parafac2Options(rank=args.rank,
                               constraints=CONSTRAINT_CASES["nonneg"],
                               backend=backend, precision=precision,
                               engine="host", check_every=args.check_every)
        run = _make_runner(bt, opts, args.iters)
        final_fit = float("nan")
        for _ in range(2):   # compile + warm
            final_fit = run()
        prepped.append({"backend": backend, "precision": precision,
                        "run": run, "final_fit": final_fit, "times": []})
    for _ in range(args.repeats):
        for case in prepped:
            t0 = time.perf_counter()
            case["final_fit"] = case["run"]()
            case["times"].append(time.perf_counter() - t0)
    out = {}
    pallas_per_iter = None
    for case in prepped:
        ts = sorted(case["times"])
        per_iter = ts[len(ts) // 2] / args.iters
        rec = {"seconds_per_iter": per_iter,
               "final_fit": case["final_fit"], "iters": args.iters,
               "n_subjects": data.n_subjects, "nnz": data.nnz}
        rel = ""
        if case["backend"] == "pallas":
            pallas_per_iter = per_iter
        elif pallas_per_iter:
            rec["speedup_vs_pallas_per_iter"] = pallas_per_iter / per_iter
            rel = f"speedup_vs_pallas={rec['speedup_vs_pallas_per_iter']:.2f}x"
        emit(f"als_fused/{ds}/{case['backend']}/{case['precision']}",
             per_iter, f"fit={case['final_fit']:.4f} {rel}".strip())
        out[f"als_fused/{ds}/{case['backend']}/{case['precision']}"] = rec
    return out


def _supervised_cases(args):
    """The ``als_supervised`` namespace: the chunked scan loop bare
    (``_make_runner``'s exact pattern) vs wrapped in a FAULTLESS
    ``repro.dist.supervisor.supervised_fit`` — identical data, init state and
    chunk lengths, the compiled chunk shared through the supervisor's
    ``chunk_cache`` seam so both sides time steady-state dispatches only.
    What remains is the supervisor's per-chunk host cost (health sentinel,
    watchdog, snapshot bookkeeping), the price of turning fault tolerance on;
    the paired median ratio is what ``--overhead-gate`` holds to budget.
    Returns ``(rows, median supervised/bare ratio)``."""
    from repro.dist.supervisor import SupervisorConfig, supervised_fit

    ds = [s.strip() for s in args.datasets.split(",") if s.strip()][0]
    data = _load(ds, args.scale, args.seed)
    bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
    opts = Parafac2Options(rank=args.rank,
                           constraints=CONSTRAINT_CASES["nonneg"],
                           engine="scan", check_every=args.check_every)
    state0 = init_state(bt, opts, seed=0)   # _make_runner's init, shared
    bare = _make_runner(bt, opts, args.iters)
    cache = {}

    def supervised():
        cfg = SupervisorConfig(chunk_cache=cache)
        _, hist, _ = supervised_fit(bt, opts, max_iters=args.iters, tol=0.0,
                                    state=state0, config=cfg)
        return hist[-1]

    fits, ratios = {}, []
    times = {"bare": [], "supervised": []}
    for name, run in (("bare", bare), ("supervised", supervised)):
        for _ in range(2):   # compile + warm
            fits[name] = run()
    for _ in range(args.repeats):
        round_t = {}
        for name, run in (("bare", bare), ("supervised", supervised)):
            t0 = time.perf_counter()
            fits[name] = run()
            round_t[name] = time.perf_counter() - t0
            times[name].append(round_t[name])
        ratios.append(round_t["supervised"] / round_t["bare"])
    overhead = sorted(ratios)[len(ratios) // 2]

    out = {}
    for name in ("bare", "supervised"):
        ts = sorted(times[name])
        per_iter = ts[len(ts) // 2] / args.iters
        rec = {"seconds_per_iter": per_iter, "final_fit": fits[name],
               "iters": args.iters, "n_subjects": data.n_subjects,
               "nnz": data.nnz}
        rel = ""
        if name == "supervised":
            rec["overhead_vs_bare_per_iter"] = overhead
            rel = f"overhead_vs_bare={overhead:.3f}x"
        emit(f"als_supervised/{ds}/{name}", per_iter,
             f"fit={fits[name]:.4f} {rel}".strip())
        out[f"als_supervised/{ds}/{name}"] = rec
    # the supervisor must not change the answer, only survive faults: a
    # faultless wrapped run is bitwise the bare chunk loop
    assert fits["supervised"] == fits["bare"], (
        fits["supervised"], fits["bare"])
    return out, overhead


def _xl_probe(args) -> dict:
    """The "larger instance" demonstration: a ≤0.1%-density geometry whose
    densified CC rectangle alone would not fit in memory, decomposed under
    SCOO. Records the avoided CC bytes and the measured SCOO footprint."""
    from repro.sparse import plan_buckets

    print("[xl-probe] generating ~33M-nonzero low-density irregular tensor "
          "(this is deliberately past the densifiable regime)")
    data = random_irregular(n_subjects=16_384, n_cols=16_384, max_rows=1000,
                            avg_nnz_per_subject=2048, seed=args.seed)
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=4,
                        sort_by="nnz")
    # what CC would have to allocate for the same plan (f32 vals alone)
    cc_bytes = sum(len(mem) * ip * cp * 4
                   for (ip, cp), mem in zip(plan.shapes, plan.members))
    bt = bucketize(data, dtype=jnp.float32, plan=plan,
                   formats=["scoo"] * plan.n_buckets)
    scoo_bytes = int(sum(
        leaf.size * leaf.dtype.itemsize
        for b in bt.buckets for leaf in jax.tree_util.tree_leaves(b)))
    opts = Parafac2Options(rank=args.rank, constraints={"v": "nonneg",
                                                        "w": "nonneg"},
                           backend="auto", engine="host")
    run = _make_runner(bt, opts, 2)
    seconds, final_fit = time_call(run, warmup=1, iters=1)
    per_iter = seconds / 2
    emit("als/xl/scoo", per_iter,
         f"fit={final_fit:.4f} scoo={scoo_bytes/2**30:.2f}GiB "
         f"cc_would_alloc={cc_bytes/2**30:.1f}GiB")
    return {
        "n_subjects": data.n_subjects, "n_cols": data.n_cols,
        "nnz": data.nnz, "seconds_per_iter_scoo": per_iter,
        "final_fit": final_fit,
        "scoo_device_bytes": scoo_bytes,
        "cc_would_alloc_bytes": int(cc_bytes),
        "cc_vs_scoo_bytes": cc_bytes / max(scoo_bytes, 1),
        "note": "cc_would_alloc_bytes is the f32 vals rectangle alone under "
                "the same bucket plan — it exceeds this host's memory, so "
                "the CC path cannot run this geometry at all",
    }


if __name__ == "__main__":
    main()
