"""End-to-end ALS benchmark: whole decompositions, engine × backend grid.

DPar2 (PAPERS.md) argues whole-decomposition time is the metric that matters —
the MTTKRP micro benchmark (`mttkrp_micro.py`) cannot see the per-iteration
host dispatch + `float(state.fit)` sync the host loop pays, which at small
ranks IS the wall-clock floor. This benchmark times `iters` ALS iterations
through each execution engine (host | scan | mesh — repro.core.engine),
backend (jnp | pallas) and constraint route (none | nonneg | nonneg_admm |
smooth — repro.core.constraints; COPA's claim is that AO-ADMM constraints
ride the same MTTKRP core at negligible extra cost, and this axis measures
exactly that) on geometry-preserving shrinks of the paper's datasets
(`choa_like` / `movielens_like`), reporting steady-state seconds/iteration
(compile excluded; the compiled callables are built once, then timed) plus a
whole-run wall time.

  PYTHONPATH=src python -m benchmarks.als_e2e --datasets choa --scale 0.002 \
      --rank 5 --iters 20 --engines host,scan \
      --constraints nonneg,nonneg_admm --json BENCH_als.json

Rows: ``als/<dataset>/<engine>/<backend>/<constraint>``. The JSON artifact is
the CI perf trajectory (BENCH_als.json); `benchmarks/compare.py` gates it
against the checked-in baseline.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core import engine as als_engine
from repro.core.parafac2 import als_step
from repro.data import choa_like, movielens_like
from benchmarks.common import calibrate, emit, time_call

# the benchmark's constraint axis: name -> per-mode specs
CONSTRAINT_CASES = {
    "none": {"v": "none", "w": "none"},
    "nonneg": {"v": "nonneg", "w": "nonneg"},            # the paper's default
    "nonneg_admm": {"v": "nonneg_admm", "w": "nonneg_admm"},
    "l1": {"v": "nonneg+l1:0.1", "w": "nonneg"},
    "smooth": {"v": "nonneg", "w": "smooth:0.1"},
}


def _load(name: str, scale: float, seed: int):
    if name == "choa":
        return choa_like(scale=scale, seed=seed)
    if name == "movielens":
        return movielens_like(scale=scale, seed=seed)
    raise ValueError(name)


def _make_runner(bt, opts, iters: int):
    """A zero-arg callable running `iters` ALS iterations the way the
    engine's fitting loop would, from a fixed init state, returning the final
    fit. Compiled callables are built ONCE here so timing excludes compile;
    donation is off so the init state survives repeated timed runs."""
    state0 = init_state(bt, opts, seed=0)

    if opts.engine == "host":
        step = jax.jit(lambda s: als_step(bt, s, opts))

        def run():
            s = state0
            f = float("nan")
            for _ in range(iters):
                s = step(s)
                f = float(s.fit)   # the host loop's per-iteration device sync
            return f

        return run

    # scan/mesh: ceil(iters / check_every) chunk dispatches, one sync each
    lengths = []
    left = iters
    while left > 0:
        n = min(opts.check_every or iters, left)
        lengths.append(n)
        left -= n
    chunks = {n: als_engine.make_als_chunk(bt, opts, n, donate=False)
              for n in set(lengths)}

    def run():
        s = state0
        f = float("nan")
        for n in lengths:
            s, fits = chunks[n](s)
            f = float(np.asarray(fits)[-1])
        return f

    return run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="choa,movielens")
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--rank", type=int, default=5)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--engines", default="host,scan",
                    help="comma list from host,scan,mesh")
    ap.add_argument("--backends", default="jnp",
                    help="comma list from jnp,pallas,auto")
    ap.add_argument("--constraints", default="nonneg",
                    help=f"comma list from {','.join(CONSTRAINT_CASES)}")
    ap.add_argument("--check-every", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repetitions per case (median reported)")
    ap.add_argument("--json", default="",
                    help="write per-case timings to this JSON file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    engines = [s.strip() for s in args.engines.split(",") if s.strip()]
    backends = [s.strip() for s in args.backends.split(",") if s.strip()]
    constraints = [s.strip() for s in args.constraints.split(",") if s.strip()]
    for c in constraints:
        if c not in CONSTRAINT_CASES:
            raise SystemExit(f"unknown constraint case {c!r}; choose from "
                             f"{', '.join(CONSTRAINT_CASES)}")
    results = {"config": {
        "scale": args.scale, "rank": args.rank, "iters": args.iters,
        "check_every": args.check_every, "platform": jax.default_backend(),
        "calib_seconds": calibrate(),
    }}

    for ds in [s.strip() for s in args.datasets.split(",") if s.strip()]:
        data = _load(ds, args.scale, args.seed)
        align = len(jax.devices()) if "mesh" in engines else 1
        bt = bucketize(data, max_buckets=4, dtype=jnp.float32,
                       subject_align=align)
        host_per_iter = {}
        for engine in engines:
            for backend in backends:
                for cname in constraints:
                    opts = Parafac2Options(
                        rank=args.rank, constraints=CONSTRAINT_CASES[cname],
                        backend=backend, engine=engine,
                        check_every=args.check_every)
                    run = _make_runner(bt, opts, args.iters)
                    seconds, final_fit = time_call(run, warmup=2,
                                                   iters=args.repeats)
                    per_iter = seconds / args.iters
                    rel = ""
                    if engine == "host":
                        host_per_iter[(backend, cname)] = per_iter
                    elif (backend, cname) in host_per_iter:
                        speedup = host_per_iter[(backend, cname)] / per_iter
                        rel = f"speedup_vs_host={speedup:.2f}x"
                    emit(f"als/{ds}/{engine}/{backend}/{cname}", per_iter,
                         f"fit={final_fit:.4f} {rel}".strip())
                    rec = {"seconds_per_iter": per_iter,
                           "seconds_total": seconds,
                           "iters": args.iters, "final_fit": final_fit,
                           "n_subjects": data.n_subjects, "nnz": data.nnz}
                    if rel:
                        rec["speedup_vs_host_per_iter"] = speedup
                    results[f"{ds}/{engine}/{backend}/{cname}"] = rec

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
