"""Paper Table 1 — synthetic scaling: one PARAFAC2 iteration, SPARTan vs the
materialized-Y + KRP baseline, for increasing nnz at R in {10, 40}.

Geometry-preserving shrink of the paper's setup (1M subjects x 5K vars x <=100
obs, 63-500M nnz): subjects scaled by --scale, variables 5000 -> 500,
max obs 100 -> 50; the four nnz columns scale the per-subject density the same
way the paper's sparsification levels do. OoM in the paper corresponds here to
the baseline's dense Y (R x J x K) blow-up — reported as the Y-bytes column.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, init_state
from repro.core.parafac2 import als_step
from repro.core.baseline import baseline_als_step
from repro.sparse import random_irregular
from benchmarks.common import emit, time_call

NNZ_LEVELS = (0.125, 0.25, 0.5, 1.0)   # mirrors 63 / 125 / 250 / 500 M


def run(scale: float = 0.002, ranks=(10, 40), iters: int = 3) -> None:
    K = max(64, int(1_000_000 * scale))
    J = 500
    for level in NNZ_LEVELS:
        data = random_irregular(
            n_subjects=K, n_cols=J, max_rows=50,
            avg_nnz_per_subject=250 * level, seed=17)
        bt = bucketize(data, max_buckets=4, dtype=jnp.float32)
        for R in ranks:
            opts = Parafac2Options(rank=R, constraints={"v": "nonneg", "w": "nonneg"})
            state = init_state(bt, opts, seed=0)
            sp = jax.jit(lambda s: als_step(bt, s, opts))
            bl = jax.jit(lambda s: baseline_als_step(bt, s, opts))
            t_sp, _ = time_call(sp, state, iters=iters)
            t_bl, _ = time_call(bl, state, iters=iters)
            y_bytes = 4 * R * J * K
            emit(f"table1/spartan/nnz{data.nnz}/R{R}", t_sp,
                 f"speedup={t_bl / t_sp:.2f}x")
            emit(f"table1/baseline/nnz{data.nnz}/R{R}", t_bl,
                 f"dense_Y_bytes={y_bytes}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    run(scale=args.scale, iters=args.iters)


if __name__ == "__main__":
    main()
