"""Perf-regression gate: current benchmark JSONs vs the checked-in baseline.

  PYTHONPATH=src python -m benchmarks.compare \
      --baseline benchmarks/BENCH_baseline.json \
      --current als=BENCH_als.json --current mttkrp=BENCH_mttkrp.json \
      --threshold 1.5 --append BENCH_trajectory.jsonl

The baseline file holds one namespace per benchmark (``als`` from
`als_e2e.py`, ``mttkrp`` from `mttkrp_micro.py`), each namespace being that
benchmark's raw ``--json`` output. Rules:

* lower-is-better timing leaves (``us_per_call``, ``seconds_per_iter``)
  REGRESS when ``current > threshold * baseline``;
* higher-is-better leaves (any key containing ``speedup``) regress when
  ``current < baseline / threshold``;
* timing leaves are gated on their deviation from the namespace's COMMON
  speed shift: with ≥3 shared timing rows the per-case ratio is divided by
  the median current/baseline ratio (self-normalization — a CI runner that
  is uniformly 2× slower than the baseline machine shifts every row equally
  and cancels out; a real regression in one case is an outlier and still
  trips). With fewer rows, the ``config.calib_seconds`` reference-workload
  timing (see `benchmarks/common.calibrate`) normalizes instead, falling
  back to raw ratios. Speedup leaves are ratios already — never normalized;
* cases missing on either side are reported but never fail (the grid may
  grow or shrink across PRs); ``seconds_total``/``relerr``/config values are
  informational only;
* ``--skip SUBSTRING`` (repeatable) exempts matching case paths from the
  gate while keeping them in the report and trajectory — CI skips
  ``/pallas`` timings, which on CPU come from interpret-mode emulation (a
  correctness tool whose wall time is meaningless and noisy).

``--append`` appends one JSON line (timestamp + all current namespaces) to a
trajectory file — CI persists it across runs via actions/cache, so the
BENCH_* artifacts accumulate the perf history of the repo.

Exit code 1 on any regression — this is the CI gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, Iterator, Tuple

# leaves the gate compares; everything else is informational
_LOWER_BETTER = ("us_per_call", "seconds_per_iter")
_HIGHER_BETTER = ("speedup",)


def _timing_leaves(tree: dict, prefix: str = "") -> Iterator[Tuple[str, str, float]]:
    """Yield (path, kind, value) for every gated numeric leaf."""
    for key, val in tree.items():
        path = f"{prefix}/{key}" if prefix else key
        if isinstance(val, dict):
            if key != "config":
                yield from _timing_leaves(val, path)
        elif isinstance(val, (int, float)):
            if any(k in key for k in _HIGHER_BETTER):
                yield path, "higher", float(val)
            elif any(k in key for k in _LOWER_BETTER):
                yield path, "lower", float(val)


def _calib(ns: dict) -> float:
    return float(ns.get("config", {}).get("calib_seconds", 0.0)) or 0.0


def compare_namespace(name: str, base: dict, cur: dict, threshold: float,
                      skip: Tuple[str, ...] = ()) -> Tuple[list, list]:
    """-> (regressions, report_rows) for one benchmark namespace."""
    b_calib, c_calib = _calib(base), _calib(cur)
    base_leaves = dict((p, (k, v)) for p, k, v in _timing_leaves(base))
    cur_leaves = dict((p, (k, v)) for p, k, v in _timing_leaves(cur))

    # common speed shift of this namespace: median per-case ratio over the
    # shared GATED lower-better rows (--skip-exempted rows are excluded —
    # they are skipped precisely because their timings are noise, so they
    # must not control the scale); calibration-workload ratio as fallback
    shared = [(cur_leaves[p][1] / v) for p, (k, v) in base_leaves.items()
              if k == "lower" and p in cur_leaves and v > 0
              and not any(s in f"{name}/{p}" for s in skip)]
    if len(shared) >= 3:
        scale = sorted(shared)[len(shared) // 2]
        how = "vs median shift"
    elif b_calib > 0 and c_calib > 0:
        scale = c_calib / b_calib
        how = "calib-normalized"
    else:
        scale = 1.0
        how = "raw"

    regressions, rows = [], []
    for path, (kind, bval) in sorted(base_leaves.items()):
        if path not in cur_leaves:
            rows.append((f"{name}/{path}", "MISSING in current", ""))
            continue
        _, cval = cur_leaves[path]
        if kind == "lower":
            ratio = (cval / bval) / scale if bval > 0 else float("inf")
            bad = ratio > threshold
            verdict = f"{ratio:.2f}x ({how})"
        else:  # higher-is-better ratio metrics, never normalized
            ratio = cval / bval if bval > 0 else float("inf")
            bad = ratio < 1.0 / threshold
            verdict = f"{ratio:.2f}x of baseline"
        if any(s in f"{name}/{path}" for s in skip):
            rows.append((f"{name}/{path}", verdict, "skipped (not gated)"))
            continue
        rows.append((f"{name}/{path}", verdict, "REGRESSED" if bad else "ok"))
        if bad:
            regressions.append(f"{name}/{path}: baseline={bval:.4g} "
                               f"current={cval:.4g} ({verdict})")
    # leaves only in current (a grown grid, or an axis rename that moved a
    # row to a new path): never gated — one line, not a wall of rows, so a
    # rename that orphans the whole namespace stays readable
    new = sorted(set(cur_leaves) - set(base_leaves))
    if new:
        rows.append((f"{name}: {len(new)} new leaf(s), ungated",
                     ", ".join(p.split("/")[0] for p in new[:4])
                     + ("..." if len(new) > 4 else ""), ""))
    return regressions, rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="checked-in baseline JSON (namespace -> benchmark output)")
    ap.add_argument("--current", action="append", default=[],
                    metavar="NAME=PATH",
                    help="current benchmark output, e.g. als=BENCH_als.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when a timed case regresses more than this factor")
    ap.add_argument("--skip", action="append", default=[], metavar="SUBSTRING",
                    help="exempt case paths containing SUBSTRING from the "
                         "gate (still reported and appended)")
    ap.add_argument("--append", default="", metavar="PATH",
                    help="append the current results as one line to this "
                         "JSONL trajectory file")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    currents: Dict[str, dict] = {}
    for spec in args.current:
        name, _, path = spec.partition("=")
        if not path:
            ap.error(f"--current needs NAME=PATH, got {spec!r}")
        with open(path) as f:
            currents[name] = json.load(f)

    all_regressions = []
    for name, cur in currents.items():
        if not isinstance(baseline.get(name), dict):
            # absent OR a non-dict stub: a brand-new namespace (e.g. a fresh
            # benchmark axis) has nothing to gate against — skip, don't crash
            print(f"[compare] namespace {name!r} not in baseline — "
                  f"new namespace, ungated")
            continue
        regs, rows = compare_namespace(name, baseline[name], cur,
                                       args.threshold, tuple(args.skip))
        for path, verdict, flag in rows:
            print(f"  {path:55s} {verdict:28s} {flag}")
        all_regressions += regs

    if args.append:
        with open(args.append, "a") as f:
            f.write(json.dumps({"ts": time.time(), **currents},
                               default=float) + "\n")
        print(f"[compare] appended run to {args.append}")

    if all_regressions:
        print(f"\n[compare] {len(all_regressions)} regression(s) "
              f"(> {args.threshold}x vs baseline):")
        for r in all_regressions:
            print("  " + r)
        return 1
    print(f"\n[compare] OK — no case regressed > {args.threshold}x vs baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
