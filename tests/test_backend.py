"""Backend-parity suite: the jnp and pallas(interpret=True) MTTKRP backends
must agree to f32 tolerance for all three modes, across odd/unaligned shapes,
empty buckets, padded subjects, and the mode1_reuse path — the contract that
makes ``Parafac2Options(backend=...)`` a pure performance knob."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.sparse import random_irregular, random_parafac2
from repro.core import Parafac2Options, bucketize, fit, init_state, als_step
from repro.core.backend import (
    AutoBackend, BACKENDS, FusedBackend, JnpBackend, PallasBackend,
    dispatch_tally, get_backend)

JNP = get_backend("jnp")
PAL = get_backend("pallas")
FUSED = get_backend("fused")

TOL = dict(rtol=1e-4, atol=1e-4)


def _setup(seed=0, K=13, J=37, R=5, col_align=4, subject_align=1, buckets=2,
           max_rows=9):
    """f32 bucketed data + factors; small-align geometry exercises odd C."""
    data = random_irregular(n_subjects=K, n_cols=J, max_rows=max_rows,
                            avg_nnz_per_subject=18, seed=seed)
    bt = bucketize(data, max_buckets=buckets, dtype=jnp.float32,
                   col_align=col_align, subject_align=subject_align)
    rng = np.random.default_rng(seed)
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, R)), jnp.float32)
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)),
                                 jnp.float32)) for b in bt.buckets]
    return bt, Ycs, H, V, W


# geometry sweep: odd/unaligned (R=5, col_align=4), kernel-aligned
# (R=8, col_align=128), rank-1, and subject padding inside buckets
GEOMETRIES = [
    dict(seed=0, K=13, J=37, R=5, col_align=4),
    dict(seed=1, K=9, J=200, R=8, col_align=128),
    dict(seed=2, K=7, J=21, R=1, col_align=8),
    dict(seed=3, K=11, J=50, R=6, col_align=4, subject_align=8),
]


@pytest.mark.parametrize("geom", GEOMETRIES)
def test_mode_parity(geom):
    bt, Ycs, H, V, W = _setup(**geom)
    K, J = bt.n_subjects, bt.n_cols
    np.testing.assert_allclose(PAL.mttkrp_mode1(bt.buckets, Ycs, V, W),
                               JNP.mttkrp_mode1(bt.buckets, Ycs, V, W), **TOL)
    np.testing.assert_allclose(PAL.mttkrp_mode2(bt.buckets, Ycs, H, W, J),
                               JNP.mttkrp_mode2(bt.buckets, Ycs, H, W, J), **TOL)
    np.testing.assert_allclose(PAL.mttkrp_mode3(bt.buckets, Ycs, V, H, K),
                               JNP.mttkrp_mode3(bt.buckets, Ycs, V, H, K), **TOL)


@pytest.mark.parametrize("geom", GEOMETRIES[:2])
def test_mode1_reuse_parity(geom):
    """YkV pre-computed (mode1_reuse) path: both backends must match each
    other AND their own non-reuse path."""
    bt, Ycs, H, V, W = _setup(**geom)
    for b, Yc in zip(bt.buckets, Ycs):
        Vg = b.gather_v(V)
        Wb = jnp.take(W, b.subject_ids, 0)
        YkV = JNP.ykv(Yc, Vg)
        # the shared Y_k V product itself must agree across backends
        np.testing.assert_allclose(PAL.ykv(Yc, Vg), YkV, **TOL)
        want = JNP.mode1(Yc, Vg, Wb, b.subject_mask)
        np.testing.assert_allclose(
            JNP.mode1(Yc, None, Wb, b.subject_mask, YkV=YkV), want, **TOL)
        np.testing.assert_allclose(
            PAL.mode1(Yc, None, Wb, b.subject_mask, YkV=YkV), want, **TOL)
        # mode-3 reuse entry point ties to the same contract
        want3 = JNP.mode3(Yc, Vg, H, b.subject_mask)
        np.testing.assert_allclose(
            PAL.mode3(Yc, None, H, b.subject_mask, YkV=YkV), want3, **TOL)


def test_empty_bucket_contributes_nothing():
    """A bucket whose subjects are all padding (mask 0) must contribute zero
    in every mode, for both backends."""
    bt, Ycs, H, V, W = _setup(seed=4, K=6, J=30, R=4, col_align=4)
    b = bt.buckets[0]
    empty = dataclasses.replace(
        b, subject_mask=jnp.zeros_like(b.subject_mask),
        col_mask=jnp.zeros_like(b.col_mask))
    Yc = Ycs[0]
    for be in (JNP, PAL):
        Wb = jnp.take(W, empty.subject_ids, 0)
        np.testing.assert_allclose(
            be.mode1(Yc, empty.gather_v(V), Wb, empty.subject_mask),
            np.zeros((4, 4)), atol=1e-6)
        np.testing.assert_allclose(
            be.mode2_compact(Yc, H, Wb, empty.col_mask, empty.subject_mask),
            np.zeros(Yc.shape).transpose(0, 2, 1), atol=1e-6)
        np.testing.assert_allclose(
            be.mode3(Yc, empty.gather_v(V), H, empty.subject_mask),
            np.zeros((empty.kb, 4)), atol=1e-6)


def test_padded_subjects_do_not_leak():
    """subject_align padding inside a bucket must not change whole-tensor
    results: compare against the same data bucketized without padding."""
    kw = dict(seed=5, K=10, J=40, R=4, col_align=4)
    bt_pad, Ycs_pad, H, V, W = _setup(subject_align=8, **kw)
    # corrupt the padded slots' Yc rows: masked slots must be ignored
    Ycs_pad = [
        jnp.where(b.subject_mask[:, None, None] > 0, Yc, 7.7)
        for b, Yc in zip(bt_pad.buckets, Ycs_pad)]
    K, J = bt_pad.n_subjects, bt_pad.n_cols
    for be in (JNP, PAL):
        m1 = be.mttkrp_mode1(bt_pad.buckets, Ycs_pad, V, W)
        m1_masked = be.mttkrp_mode1(
            bt_pad.buckets,
            [Yc * b.subject_mask[:, None, None]
             for b, Yc in zip(bt_pad.buckets, Ycs_pad)], V, W)
        np.testing.assert_allclose(m1, m1_masked, **TOL)
        m3 = be.mttkrp_mode3(bt_pad.buckets, Ycs_pad, V, H, K)
        assert m3.shape == (K, 4)


def test_auto_backend_matches_jnp_off_tpu():
    """On CPU the auto backend must dispatch every call to jnp."""
    bt, Ycs, H, V, W = _setup(seed=6)
    auto = get_backend("auto")
    if jax.default_backend() == "tpu":
        pytest.skip("auto dispatches to pallas on TPU")
    np.testing.assert_array_equal(
        np.asarray(auto.mttkrp_mode1(bt.buckets, Ycs, V, W)),
        np.asarray(JNP.mttkrp_mode1(bt.buckets, Ycs, V, W)))
    np.testing.assert_array_equal(
        np.asarray(auto.mttkrp_mode2(bt.buckets, Ycs, H, W, bt.n_cols)),
        np.asarray(JNP.mttkrp_mode2(bt.buckets, Ycs, H, W, bt.n_cols)))


def test_auto_dispatch_predicates(monkeypatch):
    """The auto backend's shape/dtype predicates, exercised for the TPU
    branch too (CI is CPU-only, so patch the platform probe)."""
    import repro.core.backend as backend_mod

    auto = AutoBackend()
    aligned = jnp.zeros((4, 8, 128), jnp.float32)
    ykv = jnp.zeros((4, 8, 8), jnp.float32)
    # off-TPU: everything dispatches to jnp regardless of geometry
    assert auto._pick(aligned) is auto._jnp
    assert auto._pick(ykv, reuse=True) is auto._jnp

    monkeypatch.setattr(backend_mod.jax, "default_backend", lambda: "tpu")
    assert auto._kernel_friendly(aligned)
    assert auto._pick(aligned) is auto._pallas
    assert not auto._kernel_friendly(jnp.zeros((4, 5, 128), jnp.float32))  # odd R
    assert not auto._kernel_friendly(jnp.zeros((4, 8, 96), jnp.float32))   # C % 128
    assert not auto._kernel_friendly(jnp.zeros((4, 8, 128), jnp.float64))  # f64
    assert not auto._kernel_friendly(None)
    # reuse entry points only need the sublane quantum on R
    assert auto._reuse_friendly(ykv)
    assert auto._pick(ykv, reuse=True) is auto._pallas
    assert not auto._reuse_friendly(jnp.zeros((4, 5, 5), jnp.float32))
    assert auto._kernel_friendly(jnp.zeros((4, 16, 256), jnp.bfloat16))


def test_get_backend_resolution():
    assert get_backend("jnp") is BACKENDS["jnp"]
    assert isinstance(get_backend("pallas"), PallasBackend)
    assert isinstance(get_backend("auto"), AutoBackend)
    be = JnpBackend()
    assert get_backend(be) is be
    with pytest.raises(ValueError, match="unknown MTTKRP backend"):
        get_backend("cuda")


def _fit_data(seed=7):
    data, _ = random_parafac2(n_subjects=12, n_cols=24, max_rows=16, rank=3,
                              density=0.8, seed=seed)
    return bucketize(data, max_buckets=2, dtype=jnp.float32, col_align=4)


@pytest.mark.parametrize("mode1_reuse", [True, False])
def test_fit_smoke_backend_trajectories(mode1_reuse):
    """fit() must run end-to-end through each backend with (near-)identical
    fit trajectories — backend="pallas" exercises kernels/ops.py throughout."""
    bt = _fit_data()
    hists = {}
    for backend in ("jnp", "pallas"):
        opts = Parafac2Options(rank=3, dtype=jnp.float32,
                               backend=backend, mode1_reuse=mode1_reuse)
        state, hist = fit(bt, opts, max_iters=5, tol=0.0, seed=0)
        assert np.isfinite(hist).all()
        hists[backend] = np.asarray(hist)
    np.testing.assert_allclose(hists["pallas"], hists["jnp"],
                               rtol=2e-3, atol=2e-3)


def test_als_step_auto_backend_runs():
    """auto backend end-to-end through als_step (picks jnp off-TPU, pallas
    on TPU — either way the step must be finite and jit-compatible)."""
    bt = _fit_data(seed=8)
    opts = Parafac2Options(rank=3, dtype=jnp.float32,
                           backend="auto")
    s0 = init_state(bt, opts, seed=0)
    s1 = jax.jit(lambda s: als_step(bt, s, opts))(s0)
    assert np.isfinite(float(s1.fit))


# ---------------------------------------------------------------------------
# fused megakernel backend: stage parity, dispatch count, mixed precision
# ---------------------------------------------------------------------------

def _setup_t(dtype, **geom):
    """Like _setup but with a selectable factor/value dtype (f64 parity)."""
    geom = dict(geom)
    seed, K, J, R = geom.pop("seed"), geom.pop("K"), geom.pop("J"), geom.pop("R")
    data = random_irregular(n_subjects=K, n_cols=J, max_rows=geom.pop("max_rows", 9),
                            avg_nnz_per_subject=18, seed=seed)
    bt = bucketize(data, max_buckets=geom.pop("buckets", 2), dtype=dtype, **geom)
    rng = np.random.default_rng(seed)
    H = jnp.asarray(rng.standard_normal((R, R)), dtype)
    V = jnp.asarray(rng.standard_normal((J, R)), dtype)
    W = jnp.asarray(rng.standard_normal((K, R)), dtype)
    Qs = [jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)), dtype)
          for b in bt.buckets]
    return bt, Qs, H, V, W


FUSED_TOLS = {jnp.float32: dict(rtol=1e-6, atol=1e-6),
              jnp.float64: dict(rtol=1e-12, atol=1e-12)}


@pytest.mark.parametrize("geom", GEOMETRIES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_stage_parity(geom, dtype):
    """Every fused ALS stage must reproduce the staged (jnp) pipeline exactly
    — f32 to 1e-6, f64 to 1e-12 — over odd/unaligned/padded geometries. The
    fused backend carries Q (never materializing Yc), so staged stages get
    Yc = b.project(Q) while fused stages get Q itself."""
    bt, Qs, H, V, W = _setup_t(dtype, **geom)
    tol = FUSED_TOLS[dtype]
    for b, Q in zip(bt.buckets, Qs):
        Vg = b.gather_v(V)
        Wb = jnp.take(W, b.subject_ids, 0)
        Yc = b.project(Q)
        # F1: X_k V + Procrustes input B in one slab pass
        XkV_s, B_s = JNP.procrustes_b_bucket(b, H, Wb, V, Vg)
        XkV_f, B_f = FUSED.procrustes_b_bucket(b, H, Wb, V, Vg)
        np.testing.assert_allclose(XkV_f, XkV_s, **tol)
        np.testing.assert_allclose(B_f, B_s, **tol)
        # F2: YkV-from-XkV + the M1 partial reduced in-dispatch
        np.testing.assert_allclose(
            FUSED.mode1_xkv_bucket(b, Q, XkV_s, Wb),
            JNP.mode1_xkv_bucket(b, Q, XkV_s, Wb), **tol)
        # F3: mode-2 compact directly from the slab (no Yc round-trip)
        np.testing.assert_allclose(
            FUSED.mode2_bucket(b, Q, H, Wb),
            JNP.mode2_bucket(b, Yc, H, Wb), **tol)
        # F4: G = Y_k V; mode-1/3 from it are the shared R x R algebra
        np.testing.assert_allclose(
            FUSED.ykv_bucket(b, Q, V), JNP.ykv_bucket(b, Yc, V), **tol)
        np.testing.assert_allclose(
            FUSED.mode1_bucket(b, Q, Wb, V), JNP.mode1_bucket(b, Yc, Wb, V),
            **tol)
        np.testing.assert_allclose(
            FUSED.mode3_bucket(b, Q, H, V), JNP.mode3_bucket(b, Yc, H, V),
            **tol)


def test_fused_empty_bucket_contributes_nothing():
    """All-padding subjects (mask 0) contribute zero through every fused
    stage, exactly like the staged backends."""
    bt, Qs, H, V, W = _setup_t(jnp.float32, seed=4, K=6, J=30, R=4, col_align=4)
    b, Q = bt.buckets[0], Qs[0]
    empty = dataclasses.replace(
        b, subject_mask=jnp.zeros_like(b.subject_mask),
        col_mask=jnp.zeros_like(b.col_mask))
    Wb = jnp.take(W, empty.subject_ids, 0)
    np.testing.assert_allclose(
        FUSED.mode1_xkv_bucket(empty, Q, Q, Wb), np.zeros((4, 4)), atol=1e-6)
    np.testing.assert_allclose(
        FUSED.mode2_bucket(empty, Q, H, Wb),
        np.zeros((empty.kb, empty.c_pad, 4)), atol=1e-6)
    np.testing.assert_allclose(
        FUSED.mode3_bucket(empty, Q, H, V), np.zeros((empty.kb, 4)), atol=1e-6)


@pytest.mark.parametrize("backend,per_bucket", [
    ("jnp", 5.0), ("pallas", 5.0), ("fused", 4.0)])
def test_dispatch_tally_per_iteration(backend, per_bucket):
    """The fused route must collapse the staged 5 bucket-stage dispatches per
    ALS iteration to 4 — the exact-parity fusion floor (eigh and the H/V
    solves are global sync points; see kernels/fused.py). Ticks fire at trace
    time, so eval_shape counts one full als_step without running it."""
    bt = _fit_data()
    opts = Parafac2Options(rank=3, dtype=jnp.float32, backend=backend)
    s0 = init_state(bt, opts, seed=0)
    with dispatch_tally() as tally:
        jax.eval_shape(lambda s: als_step(bt, s, opts), s0)
    assert sum(tally.values()) / len(bt.buckets) == per_bucket
    if backend == "fused":
        # the separate projection dispatch is gone: Q is carried, Yc never
        # materialized
        assert "project" not in tally
    else:
        assert tally["project"] == len(bt.buckets)


@pytest.mark.parametrize("backend", ["jnp", "pallas", "fused"])
def test_precision_fit_parity_choa(backend):
    """bf16/f16 compute with f32 accumulation must land within 0.1pp of the
    f32 fit on the CHOA-like workload (rank 5, 20 iterations) — the mixed
    precision contract that makes ``precision`` a pure performance knob."""
    from repro.data import choa_like

    data = choa_like(scale=0.001, seed=0)
    bt = bucketize(data, max_buckets=2, dtype=jnp.float32)
    fits = {}
    for prec in ("f32", "bf16", "f16"):
        opts = Parafac2Options(rank=5, dtype=jnp.float32, backend=backend,
                               precision=prec)
        _, hist = fit(bt, opts, max_iters=20, tol=0.0, seed=0)
        assert np.isfinite(hist).all()
        fits[prec] = float(hist[-1])
    assert abs(fits["bf16"] - fits["f32"]) < 1e-3, fits
    assert abs(fits["f16"] - fits["f32"]) < 1e-3, fits


def test_precision_option_validation():
    with pytest.raises(ValueError, match="precision"):
        Parafac2Options(rank=3, precision="f8")
    with pytest.raises(ValueError, match="precision"):
        Parafac2Options(rank=3, precision="bf16", dtype=jnp.float64)
    # f64 data keeps the f64 accumulator: precision="f32" is the identity
    Parafac2Options(rank=3, precision="f32", dtype=jnp.float64)


def test_get_backend_precision_instances():
    """get_backend(name, precision) returns configured, cached instances;
    the f32 default stays the shared singleton."""
    assert get_backend("fused") is BACKENDS["fused"]
    assert isinstance(get_backend("fused"), FusedBackend)
    be = get_backend("jnp", "bf16")
    assert isinstance(be, JnpBackend) and be.precision == "bf16"
    assert get_backend("jnp", "bf16") is be          # cached
    assert get_backend("jnp", "f32") is BACKENDS["jnp"]
    assert get_backend("fused", "f16").precision == "f16"
    with pytest.raises(ValueError):
        JnpBackend(precision="int8")


def test_auto_fused_routing(monkeypatch):
    """AutoBackend's _fused_ok predicate: fused only on TPU, CC buckets,
    sub-f64 dtype, and kernel-aligned (R % 8, C_pad % 128) geometry."""
    import repro.core.backend as backend_mod

    auto = AutoBackend()
    bt_al, _, H, V, W = _setup(seed=1, K=9, J=200, R=8, col_align=128)
    b_al = bt_al.buckets[0]
    bt_odd, _, *_ = _setup(seed=0, K=13, J=37, R=5, col_align=4)
    b_odd = bt_odd.buckets[0]
    # off-TPU: never fused (interpret-mode DMA emulation is not a win)
    assert not auto._fused_ok(b_al, 8)
    monkeypatch.setattr(backend_mod.jax, "default_backend", lambda: "tpu")
    assert auto._fused_ok(b_al, 8)
    assert not auto._fused_ok(b_al, 5)       # odd rank
    assert not auto._fused_ok(b_odd, 8)      # C_pad not lane-aligned
    bt64 = bucketize(random_irregular(n_subjects=9, n_cols=200, max_rows=9,
                                      avg_nnz_per_subject=18, seed=1),
                     max_buckets=2, dtype=jnp.float64, col_align=128)
    assert not auto._fused_ok(bt64.buckets[0], 8)   # f64 stays staged


@pytest.mark.parametrize("engine", ["host", "scan"])
def test_fused_fit_matches_staged_trajectory(engine):
    """End-to-end: the fused backend's fit trajectory tracks jnp under both
    the host and the device-resident scan engines."""
    bt = _fit_data()
    hists = {}
    for backend in ("jnp", "fused"):
        opts = Parafac2Options(rank=3, dtype=jnp.float32, backend=backend,
                               engine=engine, check_every=5)
        _, hist = fit(bt, opts, max_iters=5, tol=0.0, seed=0)
        assert np.isfinite(hist).all()
        hists[backend] = np.asarray(hist)
    np.testing.assert_allclose(hists["fused"], hists["jnp"],
                               rtol=2e-3, atol=2e-3)
