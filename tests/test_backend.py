"""Backend-parity suite: the jnp and pallas(interpret=True) MTTKRP backends
must agree to f32 tolerance for all three modes, across odd/unaligned shapes,
empty buckets, padded subjects, and the mode1_reuse path — the contract that
makes ``Parafac2Options(backend=...)`` a pure performance knob."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.sparse import random_irregular, random_parafac2
from repro.core import Parafac2Options, bucketize, fit, init_state, als_step
from repro.core.backend import (
    AutoBackend, BACKENDS, JnpBackend, PallasBackend, get_backend)

JNP = get_backend("jnp")
PAL = get_backend("pallas")

TOL = dict(rtol=1e-4, atol=1e-4)


def _setup(seed=0, K=13, J=37, R=5, col_align=4, subject_align=1, buckets=2,
           max_rows=9):
    """f32 bucketed data + factors; small-align geometry exercises odd C."""
    data = random_irregular(n_subjects=K, n_cols=J, max_rows=max_rows,
                            avg_nnz_per_subject=18, seed=seed)
    bt = bucketize(data, max_buckets=buckets, dtype=jnp.float32,
                   col_align=col_align, subject_align=subject_align)
    rng = np.random.default_rng(seed)
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, R)), jnp.float32)
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)),
                                 jnp.float32)) for b in bt.buckets]
    return bt, Ycs, H, V, W


# geometry sweep: odd/unaligned (R=5, col_align=4), kernel-aligned
# (R=8, col_align=128), rank-1, and subject padding inside buckets
GEOMETRIES = [
    dict(seed=0, K=13, J=37, R=5, col_align=4),
    dict(seed=1, K=9, J=200, R=8, col_align=128),
    dict(seed=2, K=7, J=21, R=1, col_align=8),
    dict(seed=3, K=11, J=50, R=6, col_align=4, subject_align=8),
]


@pytest.mark.parametrize("geom", GEOMETRIES)
def test_mode_parity(geom):
    bt, Ycs, H, V, W = _setup(**geom)
    K, J = bt.n_subjects, bt.n_cols
    np.testing.assert_allclose(PAL.mttkrp_mode1(bt.buckets, Ycs, V, W),
                               JNP.mttkrp_mode1(bt.buckets, Ycs, V, W), **TOL)
    np.testing.assert_allclose(PAL.mttkrp_mode2(bt.buckets, Ycs, H, W, J),
                               JNP.mttkrp_mode2(bt.buckets, Ycs, H, W, J), **TOL)
    np.testing.assert_allclose(PAL.mttkrp_mode3(bt.buckets, Ycs, V, H, K),
                               JNP.mttkrp_mode3(bt.buckets, Ycs, V, H, K), **TOL)


@pytest.mark.parametrize("geom", GEOMETRIES[:2])
def test_mode1_reuse_parity(geom):
    """YkV pre-computed (mode1_reuse) path: both backends must match each
    other AND their own non-reuse path."""
    bt, Ycs, H, V, W = _setup(**geom)
    for b, Yc in zip(bt.buckets, Ycs):
        Vg = b.gather_v(V)
        Wb = jnp.take(W, b.subject_ids, 0)
        YkV = JNP.ykv(Yc, Vg)
        # the shared Y_k V product itself must agree across backends
        np.testing.assert_allclose(PAL.ykv(Yc, Vg), YkV, **TOL)
        want = JNP.mode1(Yc, Vg, Wb, b.subject_mask)
        np.testing.assert_allclose(
            JNP.mode1(Yc, None, Wb, b.subject_mask, YkV=YkV), want, **TOL)
        np.testing.assert_allclose(
            PAL.mode1(Yc, None, Wb, b.subject_mask, YkV=YkV), want, **TOL)
        # mode-3 reuse entry point ties to the same contract
        want3 = JNP.mode3(Yc, Vg, H, b.subject_mask)
        np.testing.assert_allclose(
            PAL.mode3(Yc, None, H, b.subject_mask, YkV=YkV), want3, **TOL)


def test_empty_bucket_contributes_nothing():
    """A bucket whose subjects are all padding (mask 0) must contribute zero
    in every mode, for both backends."""
    bt, Ycs, H, V, W = _setup(seed=4, K=6, J=30, R=4, col_align=4)
    b = bt.buckets[0]
    empty = dataclasses.replace(
        b, subject_mask=jnp.zeros_like(b.subject_mask),
        col_mask=jnp.zeros_like(b.col_mask))
    Yc = Ycs[0]
    for be in (JNP, PAL):
        Wb = jnp.take(W, empty.subject_ids, 0)
        np.testing.assert_allclose(
            be.mode1(Yc, empty.gather_v(V), Wb, empty.subject_mask),
            np.zeros((4, 4)), atol=1e-6)
        np.testing.assert_allclose(
            be.mode2_compact(Yc, H, Wb, empty.col_mask, empty.subject_mask),
            np.zeros(Yc.shape).transpose(0, 2, 1), atol=1e-6)
        np.testing.assert_allclose(
            be.mode3(Yc, empty.gather_v(V), H, empty.subject_mask),
            np.zeros((empty.kb, 4)), atol=1e-6)


def test_padded_subjects_do_not_leak():
    """subject_align padding inside a bucket must not change whole-tensor
    results: compare against the same data bucketized without padding."""
    kw = dict(seed=5, K=10, J=40, R=4, col_align=4)
    bt_pad, Ycs_pad, H, V, W = _setup(subject_align=8, **kw)
    # corrupt the padded slots' Yc rows: masked slots must be ignored
    Ycs_pad = [
        jnp.where(b.subject_mask[:, None, None] > 0, Yc, 7.7)
        for b, Yc in zip(bt_pad.buckets, Ycs_pad)]
    K, J = bt_pad.n_subjects, bt_pad.n_cols
    for be in (JNP, PAL):
        m1 = be.mttkrp_mode1(bt_pad.buckets, Ycs_pad, V, W)
        m1_masked = be.mttkrp_mode1(
            bt_pad.buckets,
            [Yc * b.subject_mask[:, None, None]
             for b, Yc in zip(bt_pad.buckets, Ycs_pad)], V, W)
        np.testing.assert_allclose(m1, m1_masked, **TOL)
        m3 = be.mttkrp_mode3(bt_pad.buckets, Ycs_pad, V, H, K)
        assert m3.shape == (K, 4)


def test_auto_backend_matches_jnp_off_tpu():
    """On CPU the auto backend must dispatch every call to jnp."""
    bt, Ycs, H, V, W = _setup(seed=6)
    auto = get_backend("auto")
    if jax.default_backend() == "tpu":
        pytest.skip("auto dispatches to pallas on TPU")
    np.testing.assert_array_equal(
        np.asarray(auto.mttkrp_mode1(bt.buckets, Ycs, V, W)),
        np.asarray(JNP.mttkrp_mode1(bt.buckets, Ycs, V, W)))
    np.testing.assert_array_equal(
        np.asarray(auto.mttkrp_mode2(bt.buckets, Ycs, H, W, bt.n_cols)),
        np.asarray(JNP.mttkrp_mode2(bt.buckets, Ycs, H, W, bt.n_cols)))


def test_auto_dispatch_predicates(monkeypatch):
    """The auto backend's shape/dtype predicates, exercised for the TPU
    branch too (CI is CPU-only, so patch the platform probe)."""
    import repro.core.backend as backend_mod

    auto = AutoBackend()
    aligned = jnp.zeros((4, 8, 128), jnp.float32)
    ykv = jnp.zeros((4, 8, 8), jnp.float32)
    # off-TPU: everything dispatches to jnp regardless of geometry
    assert auto._pick(aligned) is auto._jnp
    assert auto._pick(ykv, reuse=True) is auto._jnp

    monkeypatch.setattr(backend_mod.jax, "default_backend", lambda: "tpu")
    assert auto._kernel_friendly(aligned)
    assert auto._pick(aligned) is auto._pallas
    assert not auto._kernel_friendly(jnp.zeros((4, 5, 128), jnp.float32))  # odd R
    assert not auto._kernel_friendly(jnp.zeros((4, 8, 96), jnp.float32))   # C % 128
    assert not auto._kernel_friendly(jnp.zeros((4, 8, 128), jnp.float64))  # f64
    assert not auto._kernel_friendly(None)
    # reuse entry points only need the sublane quantum on R
    assert auto._reuse_friendly(ykv)
    assert auto._pick(ykv, reuse=True) is auto._pallas
    assert not auto._reuse_friendly(jnp.zeros((4, 5, 5), jnp.float32))
    assert auto._kernel_friendly(jnp.zeros((4, 16, 256), jnp.bfloat16))


def test_get_backend_resolution():
    assert get_backend("jnp") is BACKENDS["jnp"]
    assert isinstance(get_backend("pallas"), PallasBackend)
    assert isinstance(get_backend("auto"), AutoBackend)
    be = JnpBackend()
    assert get_backend(be) is be
    with pytest.raises(ValueError, match="unknown MTTKRP backend"):
        get_backend("cuda")


def _fit_data(seed=7):
    data, _ = random_parafac2(n_subjects=12, n_cols=24, max_rows=16, rank=3,
                              density=0.8, seed=seed)
    return bucketize(data, max_buckets=2, dtype=jnp.float32, col_align=4)


@pytest.mark.parametrize("mode1_reuse", [True, False])
def test_fit_smoke_backend_trajectories(mode1_reuse):
    """fit() must run end-to-end through each backend with (near-)identical
    fit trajectories — backend="pallas" exercises kernels/ops.py throughout."""
    bt = _fit_data()
    hists = {}
    for backend in ("jnp", "pallas"):
        opts = Parafac2Options(rank=3, dtype=jnp.float32,
                               backend=backend, mode1_reuse=mode1_reuse)
        state, hist = fit(bt, opts, max_iters=5, tol=0.0, seed=0)
        assert np.isfinite(hist).all()
        hists[backend] = np.asarray(hist)
    np.testing.assert_allclose(hists["pallas"], hists["jnp"],
                               rtol=2e-3, atol=2e-3)


def test_als_step_auto_backend_runs():
    """auto backend end-to-end through als_step (picks jnp off-TPU, pallas
    on TPU — either way the step must be finite and jit-compatible)."""
    bt = _fit_data(seed=8)
    opts = Parafac2Options(rank=3, dtype=jnp.float32,
                           backend="auto")
    s0 = init_state(bt, opts, seed=0)
    s1 = jax.jit(lambda s: als_step(bt, s, opts))(s0)
    assert np.isfinite(float(s1.fit))
