"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward + one train step on CPU; output shapes + no NaNs. Decode smoke for
archs with a decode step (all 10 here — none are encoder-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import build

ARCHS = list_archs()


def _batch(cfg, rng, B=2, S=16):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.is_encdec:
        batch["encoder_frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            rng, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = bundle.init_params(rng)
    batch = _batch(cfg, rng)
    B, S = batch["tokens"].shape

    logits = jax.jit(bundle.prefill_step)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    opt = bundle.init_opt(params)
    params2, opt2, metrics = jax.jit(bundle.train_step)(params, opt, batch, 0)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss {loss}"
    # at random init, CE should be near ln(vocab) (within a loose band)
    assert 0.5 * np.log(cfg.vocab_size) < loss < 3.0 * np.log(cfg.vocab_size), loss
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l[0] - l[1]).sum()),
        jax.tree_util.tree_map(lambda a, b: (a.astype(jnp.float32), b.astype(jnp.float32)), params, params2),
        0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    rng = jax.random.PRNGKey(1)
    params = bundle.init_params(rng)
    B, max_len = 2, 32
    cache = bundle.init_cache(B, max_len)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    step = jax.jit(bundle.decode_step)
    logits, cache = step(params, cache, tok, jnp.asarray(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # a few more steps to exercise cache writes
    for p in range(1, 4):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = step(params, cache, tok, jnp.asarray(p))
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_train_loss_decreases_qwen():
    """A tiny model can memorize a fixed batch in a few steps."""
    cfg = reduced(get_config("qwen3-0.6b"))
    bundle = build(cfg, lr=3e-3, total_steps=300)  # warmup = 3 steps
    rng = jax.random.PRNGKey(2)
    params = bundle.init_params(rng)
    batch = _batch(cfg, rng, B=2, S=16)
    opt = bundle.init_opt(params)
    step = jax.jit(bundle.train_step)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch, i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_decode_matches_prefill_qwen():
    """Greedy decode logits at position t must match the prefill logits for
    the same prefix (cache correctness)."""
    cfg = reduced(get_config("qwen3-0.6b"))
    bundle = build(cfg)
    rng = jax.random.PRNGKey(3)
    params = bundle.init_params(rng)
    B, S = 2, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = bundle.prefill_step(params, {"tokens": tokens})
    cache = bundle.init_cache(B, S)
    step = jax.jit(bundle.decode_step)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.asarray(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]).astype(np.float32),
            np.asarray(full[:, t]).astype(np.float32),
            rtol=2e-2, atol=2e-2)


def test_decode_matches_prefill_mamba():
    """Recurrent-state decode equals chunked-SSD prefill (SSD duality)."""
    cfg = reduced(get_config("mamba2-780m"))
    bundle = build(cfg)
    rng = jax.random.PRNGKey(4)
    params = bundle.init_params(rng)
    B, S = 2, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = bundle.prefill_step(params, {"tokens": tokens})
    cache = bundle.init_cache(B, S)
    step = jax.jit(bundle.decode_step)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.asarray(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]).astype(np.float32),
            np.asarray(full[:, t]).astype(np.float32),
            rtol=3e-2, atol=3e-2)
