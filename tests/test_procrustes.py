"""Batched Procrustes/polar solvers: orthonormality + cross-method agreement."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.procrustes import polar_gram_eigh, polar_newton_schulz, polar_svd, solve_q


def _rand_b(seed, kb=6, i=20, r=5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((kb, i, r)))


@pytest.mark.parametrize("method", ["svd", "gram_eigh", "newton_schulz"])
def test_orthonormal_columns(method):
    B = _rand_b(0)
    Q = solve_q(B, method)
    G = jnp.einsum("kir,kil->krl", Q, Q)
    eye = jnp.eye(5)[None]
    tol = 1e-6 if method != "newton_schulz" else 1e-3
    np.testing.assert_allclose(G, jnp.broadcast_to(eye, G.shape), atol=tol)


def test_gram_eigh_matches_svd():
    B = _rand_b(1)
    np.testing.assert_allclose(polar_gram_eigh(B), polar_svd(B), atol=1e-8)


def test_newton_schulz_matches_svd():
    B = _rand_b(2)
    np.testing.assert_allclose(polar_newton_schulz(B, iters=30), polar_svd(B), atol=1e-4)


def test_padded_rows_stay_zero():
    B = np.array(_rand_b(3), copy=True)
    B[:, 15:, :] = 0.0  # padding rows
    Q = np.asarray(polar_gram_eigh(jnp.asarray(B)))
    assert np.abs(Q[:, 15:, :]).max() == 0.0


def test_polar_maximizes_trace():
    """Procrustes optimality: Q = polar(B) maximizes tr(Q^T B) over orthonormal Q."""
    B = _rand_b(4, kb=3, i=10, r=4)
    Q = polar_svd(B)
    opt = jnp.einsum("kir,kir->k", Q, B)
    rng = np.random.default_rng(0)
    for _ in range(10):
        A = rng.standard_normal((3, 10, 4))
        Qr, _ = np.linalg.qr(A)
        other = np.einsum("kir,kir->k", Qr, np.asarray(B))
        assert (np.asarray(opt) >= other - 1e-8).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), kb=st.integers(1, 5),
       i=st.integers(2, 16), r=st.integers(1, 6))
def test_property_gram_eigh_orthonormal(seed, kb, i, r):
    if i < r:
        i = r  # polar needs I >= R for full column rank in general
    B = _rand_b(seed, kb, i, r)
    Q = polar_gram_eigh(B)
    G = np.einsum("kir,kil->krl", np.asarray(Q), np.asarray(Q))
    np.testing.assert_allclose(G, np.broadcast_to(np.eye(r), G.shape), atol=1e-6)
