"""Manual (shard_map + all_to_all) MoE vs the pure-GSPMD auto path.

Runs in a subprocess with 8 placeholder devices on a (2,2,2) mesh. With a
capacity factor large enough that nothing is dropped anywhere, both paths
compute the same mathematical function, so outputs (and grads) must agree.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.dist.sharding import LM_RULES, axis_rules
    from repro.models.moe import init_moe, _moe_block_auto, _moe_block_manual

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    # no-drop capacity: local and global dispatch then agree exactly
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts * 3))
    rng = jax.random.PRNGKey(0)
    p = init_moe(rng, cfg, jnp.float32)
    B, S, d = 4, 8, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)

    def loss_auto(p, x):
        y, aux = _moe_block_auto(p, x, cfg)
        return (y.astype(jnp.float32) ** 2).sum() + 0.0 * aux, y

    def loss_manual(p, x):
        y, aux = _moe_block_manual(p, x, cfg, mesh)
        return (y.astype(jnp.float32) ** 2).sum() + 0.0 * aux, y

    with axis_rules(LM_RULES, mesh), mesh:
        (la, ya), ga = jax.jit(jax.value_and_grad(loss_auto, has_aux=True))(p, x)
        (lm, ym), gm = jax.jit(jax.value_and_grad(loss_manual, has_aux=True))(p, x)

    out = {
        "y_err": float(jnp.max(jnp.abs(ya - ym))),
        "loss_rel": float(abs(la - lm) / (abs(la) + 1e-9)),
        "g_err": float(max(jnp.max(jnp.abs(a - b))
                           for a, b in zip(jax.tree_util.tree_leaves(ga),
                                           jax.tree_util.tree_leaves(gm)))),
        "y_scale": float(jnp.max(jnp.abs(ya))),
    }
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_manual_moe_matches_auto():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", _SRC], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    scale = max(out["y_scale"], 1e-6)
    assert out["y_err"] <= 1e-4 * scale + 1e-5, out
    assert out["loss_rel"] <= 1e-5, out
    assert out["g_err"] <= 1e-3, out
