"""Shared test fixtures. NOTE: do NOT set XLA_FLAGS device-count here — smoke
tests and benchmarks must see the single real CPU device; only the dry-run
(launch/dryrun.py, run as its own process) uses 512 placeholder devices."""
import jax
import pytest

# Numerical tests on the decomposition core need f64 to assert tight algebra
# identities; model smoke tests use explicit f32/bf16 dtypes so are unaffected.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng_seed():
    return 1234
