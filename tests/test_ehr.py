"""Synthetic-EHR generator suite: the vectorized ``data/ehr.py::_build`` must
be deterministic per seed and statistically indistinguishable (geometry-wise)
from the original per-observation-loop generator it replaced."""
import numpy as np
import pytest

from repro.data import choa_like, movielens_like
from repro.data.ehr import _build
from repro.sparse.coo import IrregularCOO, SubjectCOO


def _build_reference(K, J, max_rows, mean_rows, feats_per_obs, seed,
                     phenotypes=None):
    """The pre-vectorization generator (per-observation Python loop), kept
    verbatim as the distributional reference for the geometry-stats test."""
    rng = np.random.default_rng(seed)
    subs = []
    R = 0 if phenotypes is None else phenotypes.shape[1]
    if phenotypes is None:
        pop = 1.0 / np.arange(1, J + 1) ** 0.8
        pop /= pop.sum()
    for k in range(K):
        I_k = int(np.clip(rng.poisson(mean_rows) + 1, 1, max_rows))
        rows, cols, vals = [], [], []
        if phenotypes is None:
            active = rng.choice(J, size=min(J, max(3, int(rng.poisson(feats_per_obs * 3)))),
                                replace=False, p=pop)
        else:
            r_k = rng.integers(0, R)
            w = phenotypes[:, r_k]
            active = np.argsort(-w)[: max(3, feats_per_obs * 2)]
        for i in range(I_k):
            n = max(1, int(rng.poisson(feats_per_obs)))
            picks = rng.choice(active, size=min(n, active.size), replace=False)
            rows.extend([i] * picks.size)
            cols.extend(picks.tolist())
            vals.extend(rng.poisson(2.0, picks.size) + 1.0)
        key = np.asarray(rows, np.int64) * J + np.asarray(cols, np.int64)
        uk, inv = np.unique(key, return_inverse=True)
        v = np.zeros(uk.size)
        np.add.at(v, inv, np.asarray(vals, np.float64))
        subs.append(SubjectCOO(
            rows=(uk // J).astype(np.int32),
            cols=(uk % J).astype(np.int32),
            vals=v, n_rows=I_k, n_cols=J))
    return IrregularCOO(subjects=subs, n_cols=J)


def _geometry_stats(data):
    rc = data.row_counts()
    nnz = np.asarray([s.vals.size for s in data.subjects], np.float64)
    vals = np.concatenate([s.vals for s in data.subjects])
    distinct_cols = np.asarray(
        [np.unique(s.cols).size for s in data.subjects], np.float64)
    return {
        "mean_rows": rc.mean(),
        "mean_nnz": nnz.mean(),
        "mean_val": vals.mean(),
        "mean_distinct_cols": distinct_cols.mean(),
        "nnz_per_row": (nnz / np.maximum(rc, 1)).mean(),
    }


GEOM = dict(K=400, J=300, max_rows=40, mean_rows=10, feats_per_obs=4)


def test_vectorized_build_matches_reference_geometry_stats():
    """Same seed-family, same distributions: every geometry statistic of the
    batched generator lands within a few percent of the loop reference."""
    new = _geometry_stats(_build(seed=0, **GEOM))
    ref = _geometry_stats(_build_reference(seed=0, **GEOM))
    for key in ref:
        np.testing.assert_allclose(
            new[key], ref[key], rtol=0.06,
            err_msg=f"geometry stat {key!r} drifted: "
                    f"vectorized={new[key]:.4g} reference={ref[key]:.4g}")


def test_vectorized_build_matches_reference_with_phenotypes():
    rng = np.random.default_rng(1)
    phen = rng.random((GEOM["J"], 5)) ** 4
    new = _geometry_stats(_build(seed=2, phenotypes=phen, **GEOM))
    ref = _geometry_stats(_build_reference(seed=2, phenotypes=phen, **GEOM))
    for key in ref:
        np.testing.assert_allclose(new[key], ref[key], rtol=0.06,
                                   err_msg=f"geometry stat {key!r} drifted")


def test_build_deterministic_per_seed():
    a = _build(seed=7, **GEOM)
    b = _build(seed=7, **GEOM)
    assert len(a.subjects) == len(b.subjects)
    for sa, sb in zip(a.subjects, b.subjects):
        np.testing.assert_array_equal(sa.rows, sb.rows)
        np.testing.assert_array_equal(sa.cols, sb.cols)
        np.testing.assert_array_equal(sa.vals, sb.vals)
        assert sa.n_rows == sb.n_rows
    c = _build(seed=8, **GEOM)
    assert any(sa.vals.size != sc.vals.size or not np.array_equal(sa.vals, sc.vals)
               for sa, sc in zip(a.subjects, c.subjects))


def test_build_invariants():
    data = _build(seed=3, **GEOM)
    for s in data.subjects:
        assert 1 <= s.n_rows <= GEOM["max_rows"]
        assert s.rows.size > 0
        assert (s.rows >= 0).all() and (s.rows < s.n_rows).all()
        assert (s.cols >= 0).all() and (s.cols < GEOM["J"]).all()
        assert (s.vals >= 1.0).all()       # poisson(2) + 1
        # (row, col) pairs deduplicated and sorted by the unique() pass
        key = s.rows.astype(np.int64) * GEOM["J"] + s.cols.astype(np.int64)
        assert (np.diff(key) > 0).all()


def test_public_generators_shapes():
    d = choa_like(scale=5e-5, seed=0)
    assert d.n_cols == 1_328 and d.n_subjects >= 8
    m = movielens_like(scale=4e-4, seed=0)
    assert m.n_cols == 26_096
    assert max(s.n_rows for s in m.subjects) <= 19
