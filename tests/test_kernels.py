"""Per-kernel allclose validation: Pallas (interpret=True on CPU) vs ref.py
oracles, swept over shapes and dtypes."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref, ops


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


SHAPES = [  # (K, R, C)
    (1, 8, 128),
    (3, 8, 128),
    (4, 16, 256),
    (2, 40, 384),
    (5, 8, 640),   # multiple C tiles with block_c=512
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mode1_kernel(shape, dtype):
    K, R, C = shape
    Yc = _rand((K, R, C), dtype, 0)
    Vg = _rand((K, C, R), dtype, 1)
    Wb = _rand((K, R), dtype, 2)
    out = ops.mttkrp_mode1(Yc, Vg, Wb)
    want = ref.mode1_ref(Yc, Vg, Wb)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mode2_kernel(shape, dtype):
    K, R, C = shape
    Yc = _rand((K, R, C), dtype, 3)
    H = _rand((R, R), dtype, 4)
    Wb = _rand((K, R), dtype, 5)
    out = ops.mttkrp_mode2_compact(Yc, H, Wb)
    want = ref.mode2_compact_ref(Yc, H, Wb)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mode3_kernel(shape, dtype):
    K, R, C = shape
    Yc = _rand((K, R, C), dtype, 6)
    Vg = _rand((K, C, R), dtype, 7)
    H = _rand((R, R), dtype, 8)
    out = ops.mttkrp_mode3(Yc, Vg, H)
    want = ref.mode3_ref(Yc, Vg, H)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("K,I,NB,nblocks_v", [(2, 8, 2, 4), (3, 16, 3, 8), (1, 8, 1, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_gather_matmul_kernel(K, I, NB, nblocks_v, dtype):
    L, R = 128, 8
    rng = np.random.default_rng(9)
    vals = jnp.asarray(rng.standard_normal((K, I, NB, L)), dtype)
    blk_ids = jnp.asarray(rng.integers(0, nblocks_v, (K, NB)), jnp.int32)
    V = jnp.asarray(rng.standard_normal((nblocks_v * L, R)), dtype)
    out = ops.gather_matmul(vals, blk_ids, V)
    want = ref.gather_matmul_ref(vals, blk_ids, V)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


def test_kernels_agree_with_spartan_path():
    """The Pallas kernels, fed masked bucket tensors, reproduce the pure-JAX
    SPARTan MTTKRP used by the ALS driver (end-to-end integration)."""
    from repro.sparse import random_irregular
    from repro.core import bucketize
    from repro.core import spartan

    data = random_irregular(n_subjects=7, n_cols=40, max_rows=10,
                            avg_nnz_per_subject=25, seed=21)
    R = 8
    bt = bucketize(data, max_buckets=1, dtype=jnp.float32)
    b = bt.buckets[0]
    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((data.n_subjects, R)), jnp.float32)
    H = jnp.asarray(rng.standard_normal((R, R)), jnp.float32)
    Q = jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)), jnp.float32)
    Yc = b.project(Q)
    Vg = b.gather_v(V)
    Wb = jnp.take(W, b.subject_ids, axis=0)
    # mask-premultiplied inputs for the kernels
    Yc_m = Yc * b.subject_mask[:, None, None]
    m1_kernel = ops.mttkrp_mode1(Yc_m, Vg, Wb)
    m1_jax = spartan.mode1_bucket(Yc, Vg, Wb, b.subject_mask)
    np.testing.assert_allclose(m1_kernel, m1_jax, rtol=1e-5, atol=1e-4)
    m3_kernel = ops.mttkrp_mode3(Yc_m, Vg, H)
    m3_jax = spartan.mode3_bucket(Yc, Vg, H, b.subject_mask)
    np.testing.assert_allclose(m3_kernel, m3_jax, rtol=1e-5, atol=1e-4)
