"""Randomized compression stage (repro.core.compress) — ISSUE-8 layer.

Covers the four correctness claims of the DPar2-style rsvd pass:

* the spec registry parses through the same fail-fast grammar machinery as
  the constraint layer (unknown names list the registered preprocessors);
* the compressed fit reproduces the uncompressed fit on the fixed
  choa/0.002/rank-5/20-iter parity command — the documented tolerance is
  1e-3 relative (measured gap ~4e-5: the sketch captures >99.9% of the
  energy at the default sketch_dim 2*rank+8, and the residual-corrected
  final fit is EXACT at the expanded factors, so the gap is pure ALS-path
  divergence, not approximation bias);
* every engine runs the cores unchanged: host/scan/while bitwise-identical,
  mesh to collective-reduction tolerance;
* the SCOO path sketches without densifying yet agrees with CC to
  numerical precision (shared Ω), and rank-deficient slices produce
  exactly-zero basis columns, not NaNs.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    Parafac2Options, bucketize, fit, parse_preprocess_spec,
    preprocess_summary, register_preprocess)
from repro.core import compress as cmp_mod
from repro.core.compress import PreprocessDef
from repro.data import choa_like
from repro.sparse import plan_buckets, random_irregular

f64 = jnp.float64


@pytest.fixture(scope="module")
def choa_bt():
    data = choa_like(scale=0.002, seed=0)
    return bucketize(data, max_buckets=4, dtype=f64)


@pytest.fixture(scope="module")
def small_data():
    return random_irregular(n_subjects=24, n_cols=96, max_rows=64,
                            avg_nnz_per_subject=200, seed=3)


# ---------------------------------------------------------------------------
# spec parsing + registry (the constraint layer's grammar, fail-fast)
# ---------------------------------------------------------------------------

def test_parse_spec_canonicalizes():
    pp = parse_preprocess_spec("rsvd")
    assert (pp.name, pp.spec, pp.params) == ("rsvd", "rsvd", (0, 8, 1))
    pp = parse_preprocess_spec(" rsvd:12 ")
    assert pp.spec == "rsvd:12" and pp.params == (12, 8, 1)
    pp = parse_preprocess_spec("rsvd:12:4:2")
    assert pp.spec == "rsvd:12:4:2" and pp.params == (12, 4, 2)
    assert pp.param("q") == 2
    # identity terms drop out of a composition
    assert parse_preprocess_spec("none+rsvd:12").spec == "rsvd:12"
    assert parse_preprocess_spec("none").identity
    assert parse_preprocess_spec("").identity


def test_parse_spec_fail_fast_lists_registered():
    with pytest.raises(ValueError) as ei:
        parse_preprocess_spec("bogus:3")
    msg = str(ei.value)
    assert "registered preprocessors" in msg
    assert "rsvd" in msg and "none" in msg
    with pytest.raises(ValueError, match="integer expected"):
        parse_preprocess_spec("rsvd:abc")
    with pytest.raises(ValueError, match="negative"):
        parse_preprocess_spec("rsvd:-1")
    with pytest.raises(ValueError, match="at most"):
        parse_preprocess_spec("rsvd:1:2:3:4")
    with pytest.raises(ValueError, match="compose"):
        parse_preprocess_spec("rsvd:8+rsvd:9")


def test_sketch_dim_resolution_and_floor():
    assert parse_preprocess_spec("rsvd").sketch_dim(5) == 18      # 2*5 + 8
    assert parse_preprocess_spec("rsvd:12:4").sketch_dim(5) == 16
    with pytest.raises(ValueError, match="below the model rank"):
        parse_preprocess_spec("rsvd:3").sketch_dim(5)


def test_options_parse_compress_eagerly():
    with pytest.raises(ValueError, match="registered preprocessors"):
        Parafac2Options(rank=3, compress="bogus")
    assert Parafac2Options(rank=3).compress == "none"


def test_register_preprocess_roundtrip():
    register_preprocess("idtest", PreprocessDef())
    try:
        assert "idtest" in cmp_mod.available()
        assert parse_preprocess_spec("idtest").identity
    finally:
        cmp_mod._REGISTRY.pop("idtest", None)
        parse_preprocess_spec.cache_clear()


def test_preprocess_summary_block():
    assert preprocess_summary("none") == {"spec": "none"}
    assert preprocess_summary("rsvd:12:4:2", rank=5) == {
        "spec": "rsvd:12:4:2", "sketch_dim": 16, "power_iters": 2}


def test_fit_device_refuses_compressed_opts(choa_bt):
    from repro.core.engine import fit_device

    opts = Parafac2Options(rank=3, engine="scan", compress="rsvd", dtype=f64)
    with pytest.raises(ValueError, match="core ALS only"):
        fit_device(choa_bt, opts)


# ---------------------------------------------------------------------------
# the parity command: compressed vs uncompressed fit (documented tolerance)
# ---------------------------------------------------------------------------

def test_compressed_fit_matches_uncompressed_choa(choa_bt):
    """The fixed parity command: choa scale 0.002, rank 5, 20 iters.

    Tolerance: 1e-3 RELATIVE (measured ~4e-5). The default sketch
    (S = 2*rank + 8, one power iteration) captures >99.9% of the choa
    energy, and the final fit is residual-corrected on the original data,
    so any gap is ALS trajectory divergence — bounded well below the 1%
    acceptance bar."""
    opts = Parafac2Options(rank=5, dtype=f64)
    s_un, h_un = fit(choa_bt, opts, max_iters=20, tol=0.0, seed=0)
    opts_c = dataclasses.replace(opts, compress="rsvd")
    s_c, h_c = fit(choa_bt, opts_c, max_iters=20, tol=0.0, seed=0)
    assert len(h_c) == len(h_un) == 20
    rel = abs(h_c[-1] - h_un[-1]) / abs(h_un[-1])
    assert rel < 1e-3, f"compressed fit off by {rel:.2e} relative"
    # full-space factor shapes (H/V/W never lived in core coordinates)
    assert s_c.H.shape == s_un.H.shape
    assert s_c.V.shape == s_un.V.shape
    assert jax.tree_util.tree_structure(s_c.W) == \
        jax.tree_util.tree_structure(s_un.W)


def test_pass_through_when_sketch_not_smaller(small_data):
    """r + p >= every bucket's row pad: every bucket passes through and the
    core dataset IS the original data — the trajectory matches the
    uncompressed fit exactly (identical engine, identical inputs)."""
    bt = bucketize(small_data, max_buckets=2, dtype=f64)
    opts = Parafac2Options(rank=3, dtype=f64)
    pp = parse_preprocess_spec("rsvd:64:64")
    comp = pp.apply(bt, opts, seed=0)
    assert not any(cb.compressed for cb in comp.buckets)
    _, h_un = fit(bt, opts, max_iters=6, tol=0.0, seed=0)
    _, h_c = fit(bt, dataclasses.replace(opts, compress="rsvd:64:64"),
                 max_iters=6, tol=0.0, seed=0)
    np.testing.assert_allclose(h_c[:-1], h_un[:-1], rtol=0, atol=0)
    # the last entry is residual-corrected with a FRESH Procrustes Q (the
    # engine's history uses the step-start Q): it can only improve the fit,
    # and only by a one-step margin
    assert h_c[-1] >= h_un[-1] - 1e-12
    assert abs(h_c[-1] - h_un[-1]) < 5e-3


def test_expand_q_partial_isometry_and_exact_fit(choa_bt):
    """Expanded Q_k = P_k Q̃_k is a partial isometry on live subjects
    (QᵀQ idempotent — identity when the slice has full row rank, a 0/1
    projector otherwise), and exact_fit at the expanded factors equals the
    engine-reported core fit (the module's norm_sq identity, end to end)."""
    opts = Parafac2Options(rank=4, dtype=f64)
    pp = parse_preprocess_spec("rsvd")
    comp = pp.apply(choa_bt, opts, seed=0)
    state, hist = fit(comp.data, opts, max_iters=8, tol=0.0, seed=0)
    Qs = cmp_mod.expand_q(comp, state, opts)
    for b, Q in zip(choa_bt.buckets, Qs):
        QtQ = np.einsum("kir,kil->krl", np.asarray(Q), np.asarray(Q))
        live = np.asarray(b.subject_mask) > 0
        # atol 1e-4: polar_gram_eigh's eps-regularized inverse root leaves
        # near-null directions a hair between 0 and 1
        np.testing.assert_allclose(
            np.einsum("krl,klm->krm", QtQ[live], QtQ[live]), QtQ[live],
            atol=1e-4)
        # trace(QtQ) = number of orthonormal columns, never above the rank
        tr = np.einsum("krr->k", QtQ)
        assert (tr[live] <= opts.rank + 1e-4).all()
    # the norm_sq identity at identical factors: the core-space fit (small
    # cores, ORIGINAL norm) equals the full-space fit at the expanded Q
    from repro.core import parafac2 as p2
    from repro.core.backend import get_backend

    be = get_backend(opts.backend)
    Qcs = [p2._procrustes_project(cb.core, state.H, state.V, state.W,
                                  opts, i, be)[2]
           for i, cb in enumerate(comp.buckets)]
    core_fit = float(cmp_mod.exact_fit(comp.data, state, opts, Qcs))
    exact = float(cmp_mod.exact_fit(choa_bt, state, opts, Qs))
    assert abs(exact - core_fit) < 1e-10
    # fresh Q can only improve on the engine's step-start-Q history entry
    assert exact >= hist[-2] - 1e-12


# ---------------------------------------------------------------------------
# engine parity on the cores
# ---------------------------------------------------------------------------

def test_engine_parity_on_cores(choa_bt):
    """host / scan / while(check_every=0) are bitwise-identical on the
    compressed path (same data closed over, same program); mesh agrees to
    collective-reduction tolerance."""
    base = Parafac2Options(rank=4, dtype=f64, compress="rsvd:10:6:1")
    s_host, h_host = fit(choa_bt, base, max_iters=8, tol=0.0, seed=0)
    for engine, check_every in (("scan", 4), ("scan", 0)):
        o = dataclasses.replace(base, engine=engine, check_every=check_every)
        s_e, h_e = fit(choa_bt, o, max_iters=8, tol=0.0, seed=0)
        assert np.asarray(s_e.V).tobytes() == np.asarray(s_host.V).tobytes(), \
            f"{engine}/ce={check_every} diverged from host on cores"
        np.testing.assert_allclose(h_e[-1], h_host[-1], rtol=1e-12)
    o = dataclasses.replace(base, engine="mesh", check_every=4)
    s_m, h_m = fit(choa_bt, o, max_iters=8, tol=0.0, seed=0)
    np.testing.assert_allclose(np.asarray(s_m.V), np.asarray(s_host.V),
                               atol=1e-8)
    np.testing.assert_allclose(h_m[-1], h_host[-1], atol=1e-8)


# ---------------------------------------------------------------------------
# SCOO-vs-CC sketch agreement + degenerate slices
# ---------------------------------------------------------------------------

def test_scoo_sketch_agrees_with_cc(small_data):
    """One shared Ω, one shared bucket plan: the SCOO segment-sum sketch and
    the CC dense sketch produce the same Y_k (and the same cores) to
    numerical precision — the sparse path never densifies yet loses
    nothing."""
    from repro.core.backend import get_backend
    from repro.kernels.sketch import gaussian_sketch

    rc, cc, nnzc = (small_data.row_counts(), small_data.col_counts(),
                    small_data.nnz_counts())
    plan = plan_buckets(rc, cc, max_buckets=2, nnz_counts=nnzc)
    bt_cc = bucketize(small_data, plan=plan, dtype=f64,
                      formats=["cc"] * plan.n_buckets)
    bt_scoo = bucketize(small_data, plan=plan, dtype=f64,
                        formats=["scoo"] * plan.n_buckets)
    be = get_backend("auto")
    key = jax.random.PRNGKey(7)
    Omega = gaussian_sketch(key, small_data.n_cols, 12, f64)
    for b_cc, b_scoo in zip(bt_cc.buckets, bt_scoo.buckets):
        np.testing.assert_array_equal(np.asarray(b_cc.subject_ids),
                                      np.asarray(b_scoo.subject_ids))
        Y_cc = np.asarray(be.sketch_bucket(b_cc, Omega))
        Y_scoo = np.asarray(be.sketch_bucket(b_scoo, Omega))
        np.testing.assert_allclose(Y_scoo, Y_cc[:, : Y_scoo.shape[1]],
                                   atol=1e-10)
    # end-to-end: same compressed fit from either layout
    opts = Parafac2Options(rank=3, dtype=f64, compress="rsvd:8:4:1")
    _, h_cc = fit(bt_cc, opts, max_iters=6, tol=0.0, seed=0)
    _, h_scoo = fit(bt_scoo, opts, max_iters=6, tol=0.0, seed=0)
    np.testing.assert_allclose(h_scoo[-1], h_cc[-1], atol=1e-8)


def test_degenerate_rank_deficient_slices():
    """Subjects with fewer independent rows than the sketch width get
    exactly-zero basis columns (polar_gram_eigh's degenerate limit) — no
    NaNs anywhere, basis columns orthonormal-or-zero, finite fit."""
    data = random_irregular(n_subjects=16, n_cols=64, max_rows=48,
                            avg_nnz_per_subject=60, seed=5)
    bt = bucketize(data, max_buckets=1, dtype=f64)
    opts = Parafac2Options(rank=3, dtype=f64)
    pp = parse_preprocess_spec("rsvd:10:6:2")     # S=16 < i_pad, > thin rows
    comp = pp.apply(bt, opts, seed=0)
    (cb,) = comp.buckets
    assert cb.compressed
    P = np.asarray(cb.basis)
    assert np.isfinite(P).all() and np.isfinite(np.asarray(cb.core.vals)).all()
    # PtP is an orthogonal projector of rank = the slice's effective row
    # rank: idempotent, trace bounded by the true row count — degenerate
    # slices shrink it instead of producing NaNs
    PtP = np.einsum("kis,kit->kst", P, P)
    np.testing.assert_allclose(
        np.einsum("kst,ktu->ksu", PtP, PtP), PtP, atol=1e-4)
    tr = np.einsum("kss->k", PtP)
    live = np.asarray(bt.buckets[0].subject_mask) > 0
    rows = np.asarray(bt.buckets[0].row_counts)
    assert (tr[live] <= rows[live] + 1e-6).all()
    assert (tr[~live] < 1e-12).all()           # padding subjects: zero basis
    state, hist = fit(bt, dataclasses.replace(opts, compress="rsvd:10:6:2"),
                      max_iters=5, tol=0.0, seed=0)
    assert np.isfinite(hist).all() and np.isfinite(float(state.fit))
