"""Correctness layer for the streaming service (launch/stream.py).

Covers the ISSUE-6 serving paths:
  * ``update_subjects`` against an independent dense numpy reference of the
    Q-then-w coordinate step (the same stage-3c math ``als_step`` runs,
    evaluated at FIXED H/V — ``als_step`` itself reports W solved against a
    Procrustes basis from the start of its step, so the reference, not the
    fitted W, is the ground truth here);
  * N appends + a cold drift refit reproducing a batch fit over the union
    dataset (f64; H/V bitwise, fit within 1e-8 — the service re-solves every
    subject's (Q_k, w_k) once after adopting refit factors, a
    coordinate-descent half-step that can only raise the fit);
  * CC vs SCOO append parity;
  * drift-threshold semantics (no refit below, exactly one above);
  * fail-fast payload validation and the tPARAFAC2 smooth anchor.

All tests run in f64 (tests/conftest.py enables jax x64 globally).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Parafac2Options, bucketize, fit, update_subjects
from repro.core.nnls import hals_nnls
from repro.sparse import (
    IrregularCOO, plan_buckets, random_irregular, random_parafac2,
    route_formats)
from repro.launch.stream import (
    StreamService, synthetic_stream, validate_payload)

RANK = 3
TOL = dict(rtol=0, atol=1e-10)


def _data(seed=0, n_subjects=14, n_cols=36, max_rows=24, density=0.5,
          noise=0.05):
    data, _ = random_parafac2(
        n_subjects=n_subjects, n_cols=n_cols, max_rows=max_rows, rank=RANK,
        density=density, seed=seed, noise=noise)
    return data


def _opts(**kw):
    kw.setdefault("rank", RANK)
    kw.setdefault("dtype", jnp.float64)
    return Parafac2Options(**kw)


def _dense(s):
    X = np.zeros((s.n_rows, s.n_cols))
    X[s.rows, s.cols] = s.vals
    return X


def _bucketize_like_service(data, opts, fmt):
    """The exact batch-path bucketization StreamService uses for (re)fits."""
    rc, cc, nz = data.row_counts(), data.col_counts(), data.nnz_counts()
    plan = plan_buckets(rc, cc, max_buckets=4, nnz_counts=nz,
                        sort_by="nnz" if fmt == "scoo" else "area")
    fmts = route_formats(plan, nz, format=fmt)
    return bucketize(data, dtype=opts.dtype, plan=plan, formats=fmts)


# ---------------------------------------------------------------------------
# update_subjects vs independent dense reference
# ---------------------------------------------------------------------------

def test_update_subjects_matches_dense_reference():
    """One inner iteration == the als_step stage-3 coordinate step at fixed
    H/V: SVD-polar Procrustes, then one HALS row solve, then the exact
    residual expansion — all reproduced independently in dense numpy."""
    data = _data(seed=1)
    opts = _opts(procrustes="svd")
    bt = _bucketize_like_service(data, opts, "cc")
    # enough iterations that every subject's B_k = X_k V S_k H^T is
    # well-conditioned — the polar factor (hence the reference) is only
    # unique for full-rank B_k
    state, _ = fit(bt, opts, max_iters=25, seed=0)
    H = np.asarray(state.H)
    V = np.asarray(state.V)
    W0 = np.asarray(state.W)

    W_new, resid = update_subjects(bt, state.H, state.V, opts,
                                   w_init=state.W, inner_iters=1)
    W_new, resid = np.asarray(W_new), np.asarray(resid)

    VtV = V.T @ V
    Phi = H.T @ H
    gram3 = VtV * Phi
    for k, s in enumerate(data.subjects):
        X = _dense(s)
        B = X @ V @ np.diag(W0[k]) @ H.T
        U, _, Vt = np.linalg.svd(B, full_matrices=False)
        Q = U @ Vt
        YkV = Q.T @ X @ V
        m = np.einsum("rl,rl->l", H, YkV)
        w_ref = np.asarray(hals_nnls(
            jnp.asarray(m[None]), jnp.asarray(gram3),
            jnp.asarray(W0[k][None]), sweeps=opts.nnls_sweeps))[0]
        r_ref = (np.sum(X * X)
                 - 2.0 * np.einsum("rl,rl,l->", H, YkV, w_ref)
                 + np.einsum("rl,rl,r,l->", Phi, VtV, w_ref, w_ref))
        np.testing.assert_allclose(W_new[k], w_ref, rtol=0, atol=1e-11)
        np.testing.assert_allclose(resid[k], r_ref, rtol=1e-11, atol=1e-11)


def test_update_subjects_cc_scoo_parity():
    """The incremental solve is format-agnostic: CC and SCOO buckets give
    the same rows/residuals to f64 roundoff."""
    data = _data(seed=2)
    opts = _opts()
    out = {}
    for fmt in ("cc", "scoo"):
        bt = _bucketize_like_service(data, opts, fmt)
        state, _ = fit(bt, opts, max_iters=6, seed=0)
        out[fmt] = state
    # same math path in fit → same factors; now compare the streaming solve
    # on a shared factor bundle across formats
    H, V, W = out["cc"].H, out["cc"].V, out["cc"].W
    res = {}
    for fmt in ("cc", "scoo"):
        bt = _bucketize_like_service(data, opts, fmt)
        res[fmt] = update_subjects(bt, H, V, opts, w_init=W, inner_iters=2)
    np.testing.assert_allclose(np.asarray(res["cc"][0]),
                               np.asarray(res["scoo"][0]), **TOL)
    np.testing.assert_allclose(np.asarray(res["cc"][1]),
                               np.asarray(res["scoo"][1]),
                               rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# stream parity with batch fits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["cc", "scoo"])
def test_stream_then_cold_refit_matches_batch_fit(fmt):
    """N appends followed by a cold refit reproduce a batch fit over the
    union dataset: same plan, same seed, same engine → bitwise H/V, and the
    service fit differs only by its post-refit re-solve (which cannot lower
    it)."""
    data = _data(seed=3)
    opts = _opts()
    warm, payloads = synthetic_stream(data, warm_frac=0.5, touch_frac=0.5,
                                      seed=3)
    svc, _ = StreamService.warm_start(
        warm, opts, iters=6, seed=0, batch_slots=4, drift_threshold=np.inf,
        format=fmt, refit="cold", refit_iters=30, refit_tol=1e-9)
    for p in payloads:
        svc.submit(p)
    svc.flush()

    # union of warm data + appends is EXACTLY the original dataset (new
    # subjects arrive in stream order, so compare as a multiset of slices)
    union = svc.union_data()
    assert union.n_subjects == data.n_subjects
    assert union.nnz == data.nnz
    assert (sorted((s.n_rows, _dense(s).tobytes()) for s in union.subjects)
            == sorted((s.n_rows, _dense(s).tobytes()) for s in data.subjects))

    info = svc.refit(mode="cold")
    bt = _bucketize_like_service(union, opts, fmt)
    ref_state, ref_hist = fit(bt, opts, max_iters=30, tol=1e-9, seed=0)
    np.testing.assert_array_equal(np.asarray(svc.H), np.asarray(ref_state.H))
    np.testing.assert_array_equal(np.asarray(svc.V), np.asarray(ref_state.V))
    assert info["fit"] == ref_hist[-1]
    # after adopting the refit factors the service re-solves every subject's
    # (Q_k, w_k) once to rebuild its residual ledger — a coordinate-descent
    # half-step, so stream_fit can only sit slightly ABOVE the batch fit
    # (~1e-4 at 30 unconverged iterations; exactly equal at convergence)
    assert svc.stream_fit >= ref_hist[-1] - 1e-12
    assert abs(svc.stream_fit - ref_hist[-1]) < 1e-3


def test_stream_cc_scoo_service_parity():
    """Serving the same append stream through CC and SCOO dispatch paths
    yields the same model."""
    data = _data(seed=4)
    opts = _opts()
    warm, payloads = synthetic_stream(data, warm_frac=0.5, touch_frac=0.4,
                                      seed=4)
    svcs = {}
    for fmt in ("cc", "scoo"):
        svc, _ = StreamService.warm_start(
            warm, opts, iters=6, seed=0, batch_slots=4,
            drift_threshold=np.inf, format=fmt)
        for p in payloads:
            svc.submit(p)
        svc.flush()
        svcs[fmt] = svc
    np.testing.assert_allclose(svcs["cc"].W, svcs["scoo"].W,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(svcs["cc"]._sub_resid, svcs["scoo"]._sub_resid,
                               rtol=1e-8, atol=1e-8)
    assert abs(svcs["cc"].stream_fit - svcs["scoo"].stream_fit) < 1e-9


# ---------------------------------------------------------------------------
# drift / refit policy
# ---------------------------------------------------------------------------

def _drifting_stream(seed=5):
    """Warm population from a low-rank model; appends from an unrelated
    random tensor, so the frozen factors fit them poorly and drift grows."""
    warm = _data(seed=seed, n_subjects=10)
    junk = random_irregular(n_subjects=6, n_cols=warm.n_cols, max_rows=20,
                            avg_nnz_per_subject=60, seed=seed + 1)
    payloads = [{"rows": s.rows.tolist(), "cols": s.cols.tolist(),
                 "vals": (8.0 * s.vals).tolist(), "n_rows": s.n_rows}
                for s in junk.subjects]
    return warm, payloads


def test_drift_threshold_no_refit_below_one_above():
    warm, payloads = _drifting_stream()
    opts = _opts()

    # measuring run: unbounded threshold → zero refits, drift per batch
    svc, _ = StreamService.warm_start(warm, opts, iters=8, seed=0,
                                      batch_slots=2, drift_threshold=np.inf)
    drifts = []
    for p in payloads:
        svc.submit(p)
        svc.flush()           # one batch (or less) per flush
        drifts.append(svc.drift)
    assert svc.stats()["refits"] == 0
    assert max(drifts) > 0.0

    # threshold above every observed drift → still no refit
    svc_hi, _ = StreamService.warm_start(
        warm, opts, iters=8, seed=0, batch_slots=2,
        drift_threshold=max(drifts) * 1.01)
    for p in payloads:
        svc_hi.submit(p)
    svc_hi.flush()
    assert svc_hi.stats()["refits"] == 0

    # threshold below the first batch's drift → exactly one refit,
    # triggered by that batch, and the refit resets drift below threshold
    thresh = drifts[0] * 0.9
    svc_one, _ = StreamService.warm_start(
        warm, opts, iters=8, seed=0, batch_slots=2, drift_threshold=thresh,
        refit_iters=10)
    svc_one.submit(payloads[0])
    svc_one.flush()
    st = svc_one.stats()
    assert st["refits"] == 1
    assert st["refit_at"] == [1]
    assert st["drift"] <= thresh  # refit reset the baseline
    assert svc_one.baseline_fit >= svc_one.stream_fit - 1e-12


# ---------------------------------------------------------------------------
# smooth anchor + payload validation
# ---------------------------------------------------------------------------

def test_smooth_anchor_pulls_touched_rows_toward_previous():
    data = _data(seed=6)
    opts = _opts()
    warm, payloads = synthetic_stream(data, warm_frac=0.7, touch_frac=1.0,
                                      holdout_frac=0.5, seed=6)
    touched = [p for p in payloads if "subject" in p]
    assert touched, "stream must contain accrual payloads for this test"
    moves = {}
    for lam in (0.0, 1e4):
        svc, _ = StreamService.warm_start(
            warm, opts, iters=6, seed=0, batch_slots=4,
            drift_threshold=np.inf, smooth_lam=lam, inner_iters=1)
        deltas = []
        for p in touched:
            w_before = svc.W[p["subject"]].copy()
            r = svc.append(p)
            deltas.append(float(np.linalg.norm(r.w_row - w_before)))
        moves[lam] = np.mean(deltas)
    # a huge anchor must pin the streamed rows to their previous values
    assert moves[1e4] < 0.05 * max(moves[0.0], 1e-12) or moves[1e4] < 1e-8


def test_payload_validation_fails_fast():
    n_cols, n_known = 16, 3
    ok = {"rows": [0, 1], "cols": [2, 3], "vals": [1.0, 2.0]}
    sid, block = validate_payload(dict(ok), n_cols, n_known)
    assert sid is None and block.nnz == 2 and block.n_rows == 2

    bad = [
        ("must be a mapping", [1, 2, 3]),
        ("missing required key", {"rows": [0], "cols": [0]}),
        ("lengths differ", {**ok, "vals": [1.0]}),
        ("no observations", {"rows": [], "cols": [], "vals": []}),
        ("negative row", {**ok, "rows": [-1, 0]}),
        ("column ids", {**ok, "cols": [0, n_cols]}),
        ("finite", {**ok, "vals": [1.0, float("nan")]}),
        ("n_rows", {**ok, "n_rows": 1}),
        ("subject id", {**ok, "subject": n_known}),
        ("subject id must be an int", {**ok, "subject": "zero"}),
        ("not numeric", {**ok, "vals": ["a", "b"]}),
    ]
    for msg, payload in bad:
        with pytest.raises(ValueError, match=msg):
            validate_payload(payload, n_cols, n_known)


def test_service_rejects_bad_config():
    data = _data(seed=7, n_subjects=4)
    opts = _opts()
    with pytest.raises(ValueError, match="w_layout"):
        StreamService(data.subjects, data.n_cols,
                      _opts(w_layout="bucketed"),
                      H=np.eye(RANK), V=np.zeros((data.n_cols, RANK)),
                      W=np.ones((4, RANK)))
    with pytest.raises(ValueError, match="refit"):
        StreamService(data.subjects, data.n_cols, opts, H=np.eye(RANK),
                      V=np.zeros((data.n_cols, RANK)), W=np.ones((4, RANK)),
                      refit="lukewarm")
    with pytest.raises(ValueError, match="format"):
        StreamService(data.subjects, data.n_cols, opts, H=np.eye(RANK),
                      V=np.zeros((data.n_cols, RANK)), W=np.ones((4, RANK)),
                      format="csr")


def test_padded_dispatch_reuses_one_geometry():
    """Appends with similar shapes share one compiled (geometry, format)
    entry — the jit-cache-stability property the service is built around."""
    data = _data(seed=8, n_subjects=12, max_rows=16)
    opts = _opts()
    warm, payloads = synthetic_stream(data, warm_frac=0.5, touch_frac=0.0,
                                      seed=8)
    svc, _ = StreamService.warm_start(warm, opts, iters=4, seed=0,
                                      batch_slots=2, drift_threshold=np.inf,
                                      format="cc", row_align=32, col_align=64)
    for p in payloads:
        svc.submit(p)
    svc.flush()
    st = svc.stats()
    assert st["appends"] == len(payloads)
    # generous alignment → every batch fits the first pinned rectangle
    assert st["compiled_geometries"] == 1
