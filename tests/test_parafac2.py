"""End-to-end PARAFAC2-ALS behaviour: monotone fit, recovery, option parity."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.sparse import random_parafac2, random_irregular
from repro.core import bucketize, Parafac2Options, Parafac2State, als_step, fit, init_state
from repro.core.parafac2 import reconstruct_uk


def _exact_data(seed=1, K=20, J=30, R=4):
    data, truth = random_parafac2(
        n_subjects=K, n_cols=J, max_rows=25, rank=R, density=1.0, seed=seed
    )
    return bucketize(data, max_buckets=2, dtype=jnp.float64), truth


def test_fit_monotone_nondecreasing():
    bt, _ = _exact_data()
    opts = Parafac2Options(rank=4, dtype=jnp.float64)
    _, hist = fit(bt, opts, max_iters=40, tol=0.0)
    diffs = np.diff(hist)
    assert (diffs > -1e-8).all(), f"fit decreased: min diff {diffs.min()}"


def test_exact_recovery_high_fit():
    bt, _ = _exact_data()
    opts = Parafac2Options(rank=4, dtype=jnp.float64)
    _, hist = fit(bt, opts, max_iters=250, tol=1e-12)
    assert hist[-1] > 0.95, hist[-1]


def test_sparse_data_fit_reasonable():
    data, _ = random_parafac2(
        n_subjects=25, n_cols=40, max_rows=20, rank=3, density=0.5, seed=3
    )
    bt = bucketize(data, max_buckets=3, dtype=jnp.float64)
    opts = Parafac2Options(rank=3, dtype=jnp.float64)
    _, hist = fit(bt, opts, max_iters=30, tol=0.0)
    assert hist[-1] > 0.3
    assert (np.diff(hist) > -1e-8).all()


@pytest.mark.parametrize("method", ["svd", "gram_eigh", "newton_schulz"])
def test_procrustes_methods_equivalent_fit(method):
    bt, _ = _exact_data(seed=5)
    opts = Parafac2Options(rank=4, procrustes=method, dtype=jnp.float64)
    _, hist = fit(bt, opts, max_iters=30, tol=0.0)
    assert hist[-1] > 0.7, (method, hist[-1])


def test_mode1_reuse_bitwise_equivalent():
    """The beyond-paper mode-1 cache must not change a single iteration."""
    bt, _ = _exact_data(seed=9)
    base = Parafac2Options(rank=4, mode1_reuse=False, dtype=jnp.float64)
    reuse = Parafac2Options(rank=4, mode1_reuse=True, dtype=jnp.float64)
    s0 = init_state(bt, base, seed=0)
    s_a = als_step(bt, s0, base)
    s_b = als_step(bt, s0, reuse)
    np.testing.assert_allclose(s_a.H, s_b.H, atol=1e-9)
    np.testing.assert_allclose(s_a.V, s_b.V, atol=1e-9)
    np.testing.assert_allclose(s_a.W, s_b.W, atol=1e-9)
    np.testing.assert_allclose(s_a.fit, s_b.fit, atol=1e-9)


def test_nonneg_factors_are_nonneg():
    bt, _ = _exact_data(seed=11)
    opts = Parafac2Options(rank=4, dtype=jnp.float64)
    state, _ = fit(bt, opts, max_iters=15, tol=0.0)
    assert (np.asarray(state.V) >= 0).all()
    assert (np.asarray(state.W) >= 0).all()


def test_uk_orthogonality_structure():
    """U_k^T U_k must be (approximately) invariant over k: the PARAFAC2
    constraint the Q_k H factorization enforces by construction."""
    bt, _ = _exact_data(seed=13)
    opts = Parafac2Options(rank=4, dtype=jnp.float64)
    state, _ = fit(bt, opts, max_iters=50, tol=0.0)
    uks = reconstruct_uk(bt, state, opts)
    grams = [u.T @ u for u in uks.values() if u.shape[0] >= 4]
    ref = grams[0]
    for g in grams[1:]:
        np.testing.assert_allclose(g, ref, atol=1e-6)


def test_bucketed_w_layout_equivalent():
    """w_layout='bucketed' (production shard-aligned W) must produce the same
    iterates as the global [K,R] layout."""
    from repro.core.parafac2 import w_global

    bt, _ = _exact_data(seed=21)
    g = Parafac2Options(rank=4, dtype=jnp.float64, w_layout="global")
    b = Parafac2Options(rank=4, dtype=jnp.float64, w_layout="bucketed")
    sg = init_state(bt, g, seed=0)
    sb = init_state(bt, b, seed=0)
    for _ in range(3):
        sg = als_step(bt, sg, g)
        sb = als_step(bt, sb, b)
    np.testing.assert_allclose(sg.H, sb.H, atol=1e-9)
    np.testing.assert_allclose(sg.V, sb.V, atol=1e-9)
    np.testing.assert_allclose(sg.W, np.asarray(w_global(bt, sb.W)), atol=1e-9)
    np.testing.assert_allclose(float(sg.fit), float(sb.fit), atol=1e-9)


def test_reconstruction_error_matches_fit():
    """fit reported by als_step equals explicit residual computation."""
    data, _ = random_parafac2(
        n_subjects=10, n_cols=20, max_rows=15, rank=3, density=1.0, seed=17
    )
    bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
    opts = Parafac2Options(rank=3, dtype=jnp.float64)
    state, _ = fit(bt, opts, max_iters=25, tol=0.0)
    uks = reconstruct_uk(bt, state, opts)
    V, W = np.asarray(state.V), np.asarray(state.W)
    sq = 0.0
    for k, sub in enumerate(data.subjects):
        Xk = sub.to_dense()
        Uk = uks[k]
        recon = Uk @ np.diag(W[k]) @ V.T
        sq += np.linalg.norm(Xk - recon) ** 2
    explicit_fit = 1.0 - np.sqrt(sq) / np.sqrt(data.frobenius_sq())
    # reconstruct_uk recomputes Q_k against the FINAL factors — one extra
    # Procrustes half-step — so the explicit fit may only be >= the reported
    # one, and both agree tightly near convergence.
    assert explicit_fit >= float(state.fit) - 1e-8
    np.testing.assert_allclose(float(state.fit), explicit_fit, atol=1e-3)
