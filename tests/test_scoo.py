"""SCOO-vs-CC parity suite: the O(nnz) sparse execution path must be a pure
performance/memory knob. The same bucket plan is materialized in both device
formats and every per-iteration stage — X_k V, the projection Y_k = Q^T X_k,
all three MTTKRP modes, ykv, and whole decompositions under every engine and
an ADMM-routed constraint — must agree (f64, tight tolerances; host-vs-scan
bitwise on SCOO). Also covers the CC-vs-SCOO auto-router's decisions and the
Pallas scalar-prefetch kernels in interpret mode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    Parafac2Options, SparseBucket, Bucket, bucket_format, bucketize, fit)
from repro.core import spartan
from repro.core.backend import get_backend
from repro.kernels import scoo as kscoo
from repro.data import choa_like
from repro.sparse import (
    IrregularCOO, SubjectCOO, plan_buckets, random_irregular, route_formats)

TOL = dict(rtol=0, atol=1e-10)


def _subject(rng, n_rows, n_cols, nnz):
    """One subject with exactly `nnz` distinct nonzero cells."""
    cells = rng.choice(n_rows * n_cols, size=nnz, replace=False)
    return SubjectCOO(
        rows=(cells // n_cols).astype(np.int32),
        cols=(cells % n_cols).astype(np.int32),
        vals=rng.standard_normal(nnz),
        n_rows=n_rows, n_cols=n_cols)


def _edge_data(n_cols=29):
    """Odd/unaligned geometry with an empty subject, a single-nnz subject,
    and an ultra-sparse tall subject alongside ordinary ones."""
    rng = np.random.default_rng(7)
    subs = [
        _subject(rng, 9, n_cols, 25),
        SubjectCOO(rows=np.zeros(0, np.int32), cols=np.zeros(0, np.int32),
                   vals=np.zeros(0), n_rows=3, n_cols=n_cols),   # empty
        _subject(rng, 1, n_cols, 1),                             # single nnz
        _subject(rng, 200, n_cols, 5),                           # ultra-sparse
        _subject(rng, 13, n_cols, 40),
        _subject(rng, 6, n_cols, 11),
    ]
    return IrregularCOO(subjects=subs, n_cols=n_cols)


DATASETS = {
    "edge": _edge_data,
    "random-odd": lambda: random_irregular(
        n_subjects=13, n_cols=37, max_rows=9, avg_nnz_per_subject=18,
        seed=0, nonneg=False),
    "random-padded": lambda: random_irregular(
        n_subjects=11, n_cols=50, max_rows=12, avg_nnz_per_subject=25,
        seed=3),
}


def _pair(data, *, max_buckets=3, col_align=4, subject_align=1,
          dtype=jnp.float64):
    """The SAME bucket plan in both formats -> aligned bucket pairs."""
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=max_buckets,
                        col_align=col_align)
    kw = dict(dtype=dtype, plan=plan, subject_align=subject_align)
    cc = bucketize(data, formats=["cc"] * plan.n_buckets, **kw)
    sc = bucketize(data, formats=["scoo"] * plan.n_buckets, **kw)
    return cc, sc


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_stage_parity(name):
    """Every SCOO contraction against its CC counterpart, bucket by bucket."""
    data = DATASETS[name]()
    cc, sc = _pair(data)
    rng = np.random.default_rng(1)
    R = 5
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)))
    H = jnp.asarray(rng.standard_normal((R, R)))
    for bc, bs in zip(cc.buckets, sc.buckets):
        assert isinstance(bs, SparseBucket) and isinstance(bc, Bucket)
        assert (bs.kb, bs.i_pad, bs.c_pad) == (bc.kb, bc.i_pad, bc.c_pad)
        np.testing.assert_array_equal(np.asarray(bs.cols), np.asarray(bc.cols))
        np.testing.assert_allclose(np.asarray(bs.dense_vals()),
                                   np.asarray(bc.vals), **TOL)
        Q = jnp.asarray(rng.standard_normal((bc.kb, bc.i_pad, R)))
        Wb = jnp.asarray(rng.standard_normal((bc.kb, R)))
        # formation stages
        np.testing.assert_allclose(np.asarray(bs.xk_times_v(V)),
                                   np.asarray(bc.xk_times_v(V)), **TOL)
        Yc_cc = bc.project(Q)
        np.testing.assert_allclose(np.asarray(bs.project(Q)),
                                   np.asarray(Yc_cc), **TOL)
        # native (Yc-free) MTTKRP stages vs the spartan reference on CC's Yc
        Vg = bc.gather_v(V)
        ykv_ref = jnp.einsum("krc,kcl->krl", Yc_cc, Vg)
        np.testing.assert_allclose(
            np.asarray(kscoo.ykv_scoo(bs.vals, bs.rows, bs.lcols, Q, Vg)),
            np.asarray(ykv_ref), **TOL)
        np.testing.assert_allclose(
            np.asarray(kscoo.mode1_scoo(bs.vals, bs.rows, bs.lcols, Q, Vg,
                                        Wb, bs.subject_mask)),
            np.asarray(spartan.mode1_bucket(Yc_cc, Vg, Wb, bc.subject_mask)),
            **TOL)
        np.testing.assert_allclose(
            np.asarray(kscoo.mode2_compact_scoo(
                bs.vals, bs.rows, bs.lcols, Q, H, Wb, bs.col_mask,
                bs.subject_mask, cperm=bs.cperm, col_ends=bs.col_ends)),
            np.asarray(spartan.mode2_bucket_compact(
                Yc_cc, H, Wb, bc.col_mask, bc.subject_mask)), **TOL)
        np.testing.assert_allclose(
            np.asarray(kscoo.mode3_scoo(bs.vals, bs.rows, bs.lcols, Q, Vg, H,
                                        bs.subject_mask)),
            np.asarray(spartan.mode3_bucket(Yc_cc, Vg, H, bc.subject_mask)),
            **TOL)


def test_sorted_boundary_matches_scatter_oracle():
    """The cumsum/boundary segment-sums against the order-independent
    scatter-add fallback (ends=None)."""
    data = DATASETS["edge"]()
    _, sc = _pair(data)
    rng = np.random.default_rng(2)
    R = 4
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)))
    for b in sc.buckets:
        Vg = b.gather_v(V)
        Q = jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)))
        np.testing.assert_allclose(
            np.asarray(kscoo.xk_times_v(b.vals, b.rows, b.lcols, Vg, b.i_pad,
                                        row_ends=b.row_ends)),
            np.asarray(kscoo.xk_times_v(b.vals, b.rows, b.lcols, Vg,
                                        b.i_pad)), rtol=0, atol=1e-12)
        np.testing.assert_allclose(
            np.asarray(kscoo.project(b.vals, b.rows, b.lcols, Q, b.c_pad,
                                     cperm=b.cperm, col_ends=b.col_ends)),
            np.asarray(kscoo.project(b.vals, b.rows, b.lcols, Q, b.c_pad)),
            rtol=0, atol=1e-12)


@pytest.fixture(scope="module")
def choa_small():
    return choa_like(scale=5e-5, seed=0)


@pytest.mark.parametrize("backend", ["jnp", "scoo", "auto"])
def test_fit_parity_backends(choa_small, backend):
    """Whole-decomposition parity: SCOO final fit within 1e-8 of the CC/jnp
    reference (f64, the acceptance-criterion command shape)."""
    cc, sc = _pair(choa_small, max_buckets=2, col_align=128)
    opts_cc = Parafac2Options(rank=5, dtype=jnp.float64,
                              backend="jnp")
    opts_sc = Parafac2Options(rank=5, dtype=jnp.float64,
                              backend=backend)
    _, h_cc = fit(cc, opts_cc, max_iters=20, tol=0.0, seed=0)
    _, h_sc = fit(sc, opts_sc, max_iters=20, tol=0.0, seed=0)
    np.testing.assert_allclose(h_sc, h_cc, rtol=0, atol=1e-8)


def test_fit_parity_admm_constraint(choa_small):
    """One ADMM-routed constraint through the SCOO path (dual state carried
    in aux, engines untouched)."""
    cc, sc = _pair(choa_small, max_buckets=2, col_align=128)
    kw = dict(rank=3, dtype=jnp.float64,
              constraints={"v": "nonneg_admm", "w": "nonneg_admm"})
    _, h_cc = fit(cc, Parafac2Options(backend="jnp", **kw),
                  max_iters=15, tol=0.0, seed=0)
    for backend in ("jnp", "auto"):
        _, h_sc = fit(sc, Parafac2Options(backend=backend, **kw),
                      max_iters=15, tol=0.0, seed=0)
        np.testing.assert_allclose(h_sc, h_cc, rtol=0, atol=1e-8)


@pytest.mark.parametrize("engine,atol", [("scan", 0.0), ("mesh", 1e-8)])
def test_engine_parity_scoo(choa_small, engine, atol):
    """host vs scan bitwise on SCOO (scan closes over the data like the host
    jit); mesh to eps (shard_map compiles the step differently)."""
    _, sc = _pair(choa_small, max_buckets=2, col_align=128,
                  subject_align=len(jax.devices()))
    kw = dict(rank=3, dtype=jnp.float64, backend="auto",
              check_every=4)
    _, h_host = fit(sc, Parafac2Options(engine="host", **kw),
                    max_iters=12, tol=0.0, seed=0)
    _, h_dev = fit(sc, Parafac2Options(engine=engine, **kw),
                   max_iters=12, tol=0.0, seed=0)
    if atol == 0.0:
        np.testing.assert_array_equal(h_dev, h_host)
    else:
        np.testing.assert_allclose(h_dev, h_host, rtol=0, atol=atol)


def test_fit_parity_bucketed_w(choa_small):
    """The bucketed W layout rides the SCOO path unchanged."""
    cc, sc = _pair(choa_small, max_buckets=2, col_align=128)
    kw = dict(rank=3, dtype=jnp.float64, w_layout="bucketed")
    _, h_cc = fit(cc, Parafac2Options(backend="jnp", **kw),
                  max_iters=10, tol=0.0, seed=0)
    _, h_sc = fit(sc, Parafac2Options(backend="auto", **kw),
                  max_iters=10, tol=0.0, seed=0)
    np.testing.assert_allclose(h_sc, h_cc, rtol=0, atol=1e-8)


# ---------------------------------------------------------------------------
# auto-router decisions
# ---------------------------------------------------------------------------

def test_route_formats_by_density():
    rng = np.random.default_rng(0)
    # fully dense 8x8 blocks (density 1.0 over their kept columns) +
    # ultra-sparse tall subjects: the router must split them
    def dense_block():
        r, c = np.meshgrid(np.arange(8), np.arange(8), indexing="ij")
        return SubjectCOO(rows=r.ravel().astype(np.int32),
                          cols=c.ravel().astype(np.int32),
                          vals=rng.standard_normal(64), n_rows=8, n_cols=64)
    subs = ([dense_block() for _ in range(4)]
            + [_subject(rng, 180, 64, 6) for _ in range(4)])
    data = IrregularCOO(subjects=subs, n_cols=64)
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=2,
                        col_align=4)
    dens = plan.bucket_densities(data.nnz_counts())
    fmts = route_formats(plan, data.nnz_counts(), format="auto",
                         density_threshold=0.25)
    for d, f in zip(dens, fmts):
        assert f == ("scoo" if d < 0.25 else "cc")
    assert set(fmts) == {"cc", "scoo"}   # the geometry really is mixed
    # forcing wins over density
    assert route_formats(plan, data.nnz_counts(), format="cc") == ["cc"] * 2
    assert route_formats(plan, data.nnz_counts(), format="scoo") == ["scoo"] * 2
    with pytest.raises(ValueError, match="unknown format"):
        route_formats(plan, data.nnz_counts(), format="bogus")
    # bucketize(format="auto") materializes exactly the routed classes
    bt = bucketize(data, max_buckets=2, col_align=4, format="auto")
    assert [bucket_format(b) for b in bt.buckets] == fmts


def test_plan_nnz_stats():
    data = DATASETS["random-odd"]()
    nnzc = data.nnz_counts()
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=nnzc, max_buckets=3, col_align=4,
                        sort_by="nnz")
    assert plan.nnz_pads is not None
    for npad, mem in zip(plan.nnz_pads, plan.members):
        assert npad >= int(nnzc[mem].max())
        assert npad % 8 == 0
    assert 0.0 <= plan.nnz_waste(nnzc) < 1.0
    assert sum(plan.bucket_nnz(nnzc)) == data.nnz
    stats = plan.stats(data.row_counts(), data.col_counts(), nnzc,
                       formats=["scoo"] * plan.n_buckets)
    assert all(s["format"] == "scoo" and "density" in s and "nnz_pad" in s
               for s in stats)
    with pytest.raises(ValueError, match="sort_by='nnz'"):
        plan_buckets(data.row_counts(), data.col_counts(), sort_by="nnz")


def test_mixed_format_fit_runs(choa_small):
    """A Bucketed that genuinely mixes CC and SCOO buckets fits fine."""
    data = choa_small
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=2)
    bt = bucketize(data, dtype=jnp.float64, plan=plan, formats=["cc", "scoo"])
    assert [bucket_format(b) for b in bt.buckets] == ["cc", "scoo"]
    _, hist = fit(bt, Parafac2Options(rank=3, dtype=jnp.float64,
                                      backend="auto"),
                  max_iters=5, tol=0.0, seed=0)
    assert len(hist) == 5 and np.isfinite(hist).all()


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode on CPU)
# ---------------------------------------------------------------------------

def test_pallas_scoo_kernels_interpret():
    data = DATASETS["edge"]()
    _, sc = _pair(data, dtype=jnp.float32)
    rng = np.random.default_rng(5)
    R = 4
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)), jnp.float32)
    for b in sc.buckets:
        Vg = b.gather_v(V)
        Q = jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)), jnp.float32)
        ref_x = kscoo.xk_times_v(b.vals, b.rows, b.lcols, Vg, b.i_pad,
                                 row_ends=b.row_ends)
        pal_x = kscoo.xk_times_v(b.vals, b.rows, b.lcols, Vg, b.i_pad,
                                 use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(pal_x), np.asarray(ref_x),
                                   rtol=1e-4, atol=1e-4)
        ref_p = kscoo.project(b.vals, b.rows, b.lcols, Q, b.c_pad,
                              cperm=b.cperm, col_ends=b.col_ends)
        pal_p = kscoo.project(b.vals, b.rows, b.lcols, Q, b.c_pad,
                              use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(pal_p), np.asarray(ref_p),
                                   rtol=1e-4, atol=1e-4)


def test_pallas_backend_scoo_buckets():
    """PallasBackend's bucket-level stages route SCOO buckets through the
    Pallas segment-sum kernels and agree with the jnp route (f32)."""
    data = DATASETS["random-padded"]()
    _, sc = _pair(data, dtype=jnp.float32)
    rng = np.random.default_rng(6)
    R = 4
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)), jnp.float32)
    pal, ref = get_backend("pallas"), get_backend("jnp")
    for b in sc.buckets:
        np.testing.assert_allclose(np.asarray(pal.xkv_bucket(b, V)),
                                   np.asarray(ref.xkv_bucket(b, V)),
                                   rtol=1e-4, atol=1e-4)
        Q = jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)), jnp.float32)
        np.testing.assert_allclose(np.asarray(pal.project_bucket(b, Q)),
                                   np.asarray(ref.project_bucket(b, Q)),
                                   rtol=1e-4, atol=1e-4)


def test_nnz_offsets_uniform():
    _, sc = _pair(DATASETS["edge"]())
    for b in sc.buckets:
        np.testing.assert_array_equal(
            np.asarray(b.nnz_offsets),
            np.arange(b.kb, dtype=np.int32) * b.n_pad)


def test_pallas_block_skip_explicit_zero_values():
    """Explicit zero-VALUED triplets are legal; the Pallas block-skip must
    key on the true nnz_counts (or skip nothing), never on vals != 0 —
    counting values would drop real entries that follow a stored zero."""
    Kb, N, I, C, R = 1, 4, 4, 4, 2
    vals = jnp.asarray([[0.0, 0.0, 2.0, 3.0]], jnp.float32)
    rows = jnp.asarray([[0, 0, 1, 2]], jnp.int32)
    lcols = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    Vg = jnp.ones((Kb, C, R), jnp.float32)
    ref = kscoo.xk_times_v(vals, rows, lcols, Vg, I)
    assert float(jnp.abs(ref).sum()) > 0
    for nnz_counts in (None, jnp.asarray([4], jnp.int32)):
        out = kscoo.xk_times_v(vals, rows, lcols, Vg, I,
                               nnz_counts=nnz_counts, use_pallas=True,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    Q = jnp.ones((Kb, I, R), jnp.float32)
    ref_p = kscoo.project(vals, rows, lcols, Q, C)
    for nnz_counts in (None, jnp.asarray([4], jnp.int32)):
        out_p = kscoo.project(vals, rows, lcols, Q, C,
                              nnz_counts=nnz_counts, use_pallas=True,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref_p),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# property tests (hypothesis when available, seeded replay otherwise)
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, st  # noqa: E402
from repro.sparse import SCOO_DENSITY_THRESHOLD, fixed_plan  # noqa: E402


def _random_geometry(seed):
    """A random ragged dataset spanning dense-ish and ultra-sparse subjects
    so the auto-router sees both sides of the threshold."""
    rng = np.random.default_rng(seed)
    n_cols = int(rng.integers(8, 60))
    subs = []
    for _ in range(int(rng.integers(3, 12))):
        n_rows = int(rng.integers(1, 40))
        cap = n_rows * n_cols
        nnz = int(rng.integers(1, min(cap, 200) + 1))
        subs.append(_subject(rng, n_rows, n_cols, nnz))
    return IrregularCOO(subjects=subs, n_cols=n_cols)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_route_formats_respects_density_threshold_property(seed):
    """For ANY geometry, the auto-router's per-bucket decision is exactly
    the 0.25 density rule (density measured over the padded CC cells)."""
    data = _random_geometry(seed)
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(),
                        max_buckets=int(np.random.default_rng(seed).integers(1, 5)),
                        row_align=4, col_align=4)
    dens = plan.bucket_densities(data.nnz_counts())
    fmts = route_formats(plan, data.nnz_counts(), format="auto")
    assert len(fmts) == plan.n_buckets
    for d, f in zip(dens, fmts):
        assert f == ("scoo" if d < SCOO_DENSITY_THRESHOLD else "cc")
    # forcing a format always overrides the density rule
    assert route_formats(plan, data.nnz_counts(), format="cc") == \
        ["cc"] * plan.n_buckets
    assert route_formats(plan, data.nnz_counts(), format="scoo") == \
        ["scoo"] * plan.n_buckets


def _device_nnz_and_sum(b):
    vals = np.asarray(b.vals, dtype=np.float64)
    return int(np.count_nonzero(vals)), float(vals.sum())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_mixed_bucketize_roundtrips_nnz_property(seed):
    """bucketize(format="auto") over ANY geometry materializes every
    nonzero exactly once across its mixed CC/SCOO buckets — no drops, no
    duplicates (value sums match in both formats' staging paths)."""
    data = _random_geometry(seed)
    bt = bucketize(data, max_buckets=3, row_align=4, col_align=4,
                   format="auto", dtype=jnp.float64)
    assert bt.n_subjects == data.n_subjects
    got_nnz = 0
    got_sum = 0.0
    for b in bt.buckets:
        n, s = _device_nnz_and_sum(b)
        got_nnz += n
        got_sum += s
    want_sum = float(sum(s.vals.sum() for s in data.subjects))
    assert got_nnz == data.nnz
    np.testing.assert_allclose(got_sum, want_sum, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fixed_plan_roundtrips_nnz_property(seed):
    """The streaming service's pinned-geometry bucketize (fixed_plan) is
    also drop-free for any batch that fits the rectangle, in both formats."""
    data = _random_geometry(seed)
    i_pad = max(s.n_rows for s in data.subjects)
    c_pad = max(s.nonzero_cols().size for s in data.subjects)
    n_pad = max(s.nnz for s in data.subjects)
    for fmt in ("cc", "scoo"):
        plan = fixed_plan(data.n_subjects, i_pad, c_pad,
                          nnz_pad=n_pad if fmt == "scoo" else None)
        bt = bucketize(data, plan=plan, formats=[fmt], dtype=jnp.float64)
        got = sum(_device_nnz_and_sum(b)[0] for b in bt.buckets)
        assert got == data.nnz
