"""SPARTan MTTKRP modes vs. the materialized-KRP baseline (paper Alg. 3 vs.
Tensor-Toolbox-style reference), plus hypothesis property tests."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.sparse import random_irregular
from repro.core import bucketize
from repro.core.backend import get_backend
from repro.core.baseline import (
    baseline_mode1,
    baseline_mode2,
    baseline_mode3,
    dense_y,
    khatri_rao,
)


def _random_setup(seed, K=17, J=23, max_rows=12, R=5, buckets=3):
    rng = np.random.default_rng(seed)
    data = random_irregular(
        n_subjects=K, n_cols=J, max_rows=max_rows, avg_nnz_per_subject=30, seed=seed
    )
    bt = bucketize(data, max_buckets=buckets, dtype=jnp.float64)
    H = jnp.asarray(rng.standard_normal((R, R)))
    V = jnp.asarray(rng.standard_normal((J, R)))
    W = jnp.asarray(rng.standard_normal((K, R)))
    Ycs = [jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R))).transpose(0, 2, 1) @ b.vals
           if False else b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R))))
           for b in bt.buckets]
    return data, bt, Ycs, H, V, W


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("R", [1, 3, 8])
def test_modes_match_baseline(seed, R):
    rng = np.random.default_rng(seed)
    data = random_irregular(n_subjects=11, n_cols=19, max_rows=9,
                            avg_nnz_per_subject=25, seed=seed)
    K, J = data.n_subjects, data.n_cols
    bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
    H = jnp.asarray(rng.standard_normal((R, R)))
    V = jnp.asarray(rng.standard_normal((J, R)))
    W = jnp.asarray(rng.standard_normal((K, R)))
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R))))
           for b in bt.buckets]
    Y = dense_y(bt.buckets, Ycs, J, K)

    be = get_backend("jnp")
    M1 = be.mttkrp_mode1(bt.buckets, Ycs, V, W)
    M2 = be.mttkrp_mode2(bt.buckets, Ycs, H, W, J)
    M3 = be.mttkrp_mode3(bt.buckets, Ycs, V, H, K)

    np.testing.assert_allclose(M1, baseline_mode1(Y, V, W), atol=1e-10)
    np.testing.assert_allclose(M2, baseline_mode2(Y, H, W), atol=1e-10)
    np.testing.assert_allclose(M3, baseline_mode3(Y, H, V), atol=1e-10)


def test_mode1_reuse_identity():
    """Y_k V == Q_k^T (X_k V): the beyond-paper mode-1 cache is exact."""
    rng = np.random.default_rng(7)
    data = random_irregular(n_subjects=9, n_cols=15, max_rows=8,
                            avg_nnz_per_subject=20, seed=7)
    R = 4
    bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)))
    for b in bt.buckets:
        Q = jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R)))
        Yc = b.project(Q)
        via_cc = jnp.einsum("krc,kcl->krl", Yc, b.gather_v(V))
        via_reuse = jnp.einsum("kir,kil->krl", Q, b.xk_times_v(V))
        np.testing.assert_allclose(via_cc, via_reuse, atol=1e-10)


def test_khatri_rao_definition():
    A = jnp.asarray(np.arange(6.0).reshape(3, 2))
    B = jnp.asarray(np.arange(8.0).reshape(4, 2))
    KR = khatri_rao(A, B)
    assert KR.shape == (12, 2)
    # column r is kron(A[:,r], B[:,r])
    for r in range(2):
        np.testing.assert_allclose(KR[:, r], np.kron(A[:, r], B[:, r]))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    K=st.integers(2, 12),
    J=st.integers(4, 24),
    R=st.integers(1, 6),
)
def test_property_modes_match(seed, K, J, R):
    """Property: for arbitrary geometry, SPARTan modes equal the baseline."""
    rng = np.random.default_rng(seed)
    data = random_irregular(n_subjects=K, n_cols=J, max_rows=7,
                            avg_nnz_per_subject=12, seed=seed)
    bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
    H = jnp.asarray(rng.standard_normal((R, R)))
    V = jnp.asarray(rng.standard_normal((J, R)))
    W = jnp.asarray(rng.standard_normal((K, R)))
    Ycs = [b.project(jnp.asarray(rng.standard_normal((b.kb, b.i_pad, R))))
           for b in bt.buckets]
    Y = dense_y(bt.buckets, Ycs, J, K)
    be = get_backend("jnp")
    M1 = be.mttkrp_mode1(bt.buckets, Ycs, V, W)
    M3 = be.mttkrp_mode3(bt.buckets, Ycs, V, H, K)
    np.testing.assert_allclose(M1, baseline_mode1(Y, V, W), atol=1e-8)
    np.testing.assert_allclose(M3, baseline_mode3(Y, H, V), atol=1e-8)
