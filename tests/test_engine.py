"""Engine-parity suite: host / scan / mesh must walk the same fit trajectory.

The scan engine closes over the data exactly like the host loop's jit, so its
trajectory is bitwise host's; the mesh engine compiles under shard_map
(different fusion), so it gets a small epsilon. Both jnp and pallas MTTKRP
backends are covered (pallas in interpret mode on CPU — tiny cases only).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.data import choa_like
from repro.sparse import random_parafac2
from repro.core import ENGINES, Parafac2Options, bucketize, fit, init_state
from repro.core import engine as als_engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def choa_bt():
    """Small CHOA-geometry dataset (K≈23), f64 for tight parity asserts."""
    data = choa_like(scale=5e-5, seed=0)
    return bucketize(data, max_buckets=2, dtype=jnp.float64)


def _traj(bt, engine, *, backend="jnp", check_every=4, iters=12, tol=0.0,
          rank=3, dtype=jnp.float64):
    opts = Parafac2Options(rank=rank, dtype=dtype, engine=engine,
                           backend=backend, check_every=check_every)
    state, hist = fit(bt, opts, max_iters=iters, tol=tol, seed=0)
    return state, np.asarray(hist)


def test_scan_matches_host_trajectory(choa_bt):
    sh, hh = _traj(choa_bt, "host")
    ss, hs = _traj(choa_bt, "scan", check_every=5)   # chunks 5,5,2
    assert len(hh) == len(hs)
    np.testing.assert_allclose(hs, hh, rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(ss.V), np.asarray(sh.V), atol=1e-10)
    np.testing.assert_allclose(np.asarray(ss.W), np.asarray(sh.W), atol=1e-10)


def test_while_variant_matches_host_trajectory(choa_bt):
    """check_every=0: the single-dispatch lax.while_loop engine."""
    _, hh = _traj(choa_bt, "host")
    _, hw = _traj(choa_bt, "scan", check_every=0)
    np.testing.assert_allclose(hw, hh, rtol=0, atol=1e-12)


def test_mesh_matches_host_trajectory(choa_bt):
    """shard_map compiles the step differently, so epsilon not bitwise."""
    _, hh = _traj(choa_bt, "host")
    _, hm = _traj(choa_bt, "mesh", check_every=4)
    np.testing.assert_allclose(hm, hh, rtol=0, atol=1e-8)


def test_mesh_bucketed_w_matches_host(choa_bt):
    opts_kw = dict(rank=3, dtype=jnp.float64, w_layout="bucketed")
    sh, hh = fit(choa_bt, Parafac2Options(engine="host", **opts_kw),
                 max_iters=8, tol=0.0, seed=0)
    sm, hm = fit(choa_bt, Parafac2Options(engine="mesh", check_every=4, **opts_kw),
                 max_iters=8, tol=0.0, seed=0)
    np.testing.assert_allclose(np.asarray(hm), np.asarray(hh), atol=1e-8)
    assert isinstance(sm.W, tuple)


@pytest.mark.parametrize("engine", ["scan", "mesh"])
def test_engine_parity_pallas_backend(engine):
    """Same-engine parity with the pallas backend (interpret mode on CPU —
    keep it tiny). f32: the kernels accumulate in f32."""
    data, _ = random_parafac2(n_subjects=12, n_cols=24, max_rows=12, rank=3,
                              density=1.0, seed=3)
    bt = bucketize(data, max_buckets=1, dtype=jnp.float32)
    _, hh = _traj(bt, "host", backend="pallas", iters=4, dtype=jnp.float32)
    _, he = _traj(bt, engine, backend="pallas", check_every=2, iters=4,
                  dtype=jnp.float32)
    np.testing.assert_allclose(he, hh, rtol=0, atol=1e-5)


def test_fit_history_nondecreasing_on_choa(choa_bt):
    for engine in ("host", "scan", "mesh"):
        _, hist = _traj(choa_bt, engine, iters=15)
        diffs = np.diff(hist)
        assert (diffs > -1e-9).all(), (engine, diffs.min())


def test_while_variant_stops_like_host(choa_bt):
    """On-device tol stopping must reproduce the host rule exactly: same
    iteration count, same final fit."""
    tol = 3e-4
    _, hh = _traj(choa_bt, "host", iters=50, tol=tol)
    _, hw = _traj(choa_bt, "scan", check_every=0, iters=50, tol=tol)
    assert len(hh) < 50, "tol never hit — test geometry too hard"
    assert len(hw) == len(hh)
    np.testing.assert_allclose(hw, hh, rtol=0, atol=1e-12)


def test_scan_chunked_tol_overshoots_at_most_one_chunk(choa_bt):
    """Chunked convergence stops within check_every-1 iterations of host and
    history stays consistent with the returned state."""
    tol = 3e-4
    state_h, hh = _traj(choa_bt, "host", iters=50, tol=tol)
    state_s, hs = _traj(choa_bt, "scan", check_every=4, iters=50, tol=tol)
    assert len(hh) <= len(hs) < len(hh) + 4
    np.testing.assert_allclose(hs[: len(hh)], hh, rtol=0, atol=1e-12)
    assert hs[-1] == pytest.approx(float(state_s.fit), abs=1e-12)


def test_unknown_engine_raises(choa_bt):
    opts = Parafac2Options(rank=3, engine="warp")
    with pytest.raises(ValueError, match="engine"):
        fit(choa_bt, opts, max_iters=2)
    assert "warp" not in ENGINES


def test_mesh_divisibility_check(choa_bt):
    """_check_divisible rejects bucket subject counts the shard count does
    not divide (the error tells the user to re-bucketize)."""
    opts = Parafac2Options(rank=3, dtype=jnp.float64)
    state = init_state(choa_bt, opts, seed=0)
    kb = choa_bt.buckets[0].kb
    with pytest.raises(ValueError, match="subject_align"):
        als_engine._check_divisible(choa_bt, state, kb + 1)
    als_engine._check_divisible(choa_bt, state, 1)  # 1 shard always fine


@pytest.mark.slow
def test_mesh_engine_multidevice_subprocess():
    """The real thing: 4 host placeholder devices, data sharded 4 ways under
    shard_map, explicit psums — trajectory must match the host engine."""
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        from repro.data import choa_like
        from repro.core import Parafac2Options, bucketize, fit

        assert len(jax.devices()) == 4
        data = choa_like(scale=5e-5, seed=0)
        bt = bucketize(data, max_buckets=2, dtype=jnp.float64,
                       subject_align=4)
        kw = dict(rank=3, dtype=jnp.float64)
        _, hh = fit(bt, Parafac2Options(engine="host", **kw),
                    max_iters=8, tol=0.0, seed=0)
        _, hm = fit(bt, Parafac2Options(engine="mesh", check_every=4, **kw),
                    max_iters=8, tol=0.0, seed=0)
        np.testing.assert_allclose(np.asarray(hm), np.asarray(hh), atol=1e-8)
        print("MESH4_OK", hh[-1])
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH4_OK" in proc.stdout
