"""End-to-end driver tests: train (checkpoint/resume/fault), decompose, serve
sampling — the (b) deliverable exercised through its CLI entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.launch import decompose as decompose_mod
from repro.launch.serve import sample_token


def test_train_driver_end_to_end(tmp_path):
    out = train_mod.main([
        "--arch", "qwen3-0.6b", "--reduce", "--steps", "25", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--lr", "3e-3", "--log-every", "100",
    ])
    assert out["last_loss"] < out["first_loss"]


def test_train_driver_resume_and_fault(tmp_path):
    train_mod.main([
        "--arch", "qwen3-0.6b", "--reduce", "--steps", "12", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
        "--log-every", "100",
    ])
    out = train_mod.main([
        "--arch", "qwen3-0.6b", "--reduce", "--steps", "20", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
        "--resume", "auto", "--fail-at", "15", "--log-every", "100",
    ])
    # resumed past step 12 and survived the injected fault at 15
    assert np.isfinite(out["last_loss"])


def test_decompose_driver_synthetic():
    out = decompose_mod.main([
        "--dataset", "synthetic", "--scale", "0.005", "--rank", "4",
        "--iters", "8",
    ])
    assert 0.0 < out["fit"] <= 1.0
    assert out["iters"] >= 2


def test_decompose_driver_engine_tol_json(tmp_path):
    """--engine scan + --tol + --json: the scan engine's final fit matches
    the host engine's, and the JSON artifact is the machine-readable summary
    CI/benchmarks consume."""
    import json

    path = tmp_path / "out.json"
    common = ["--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
              "--iters", "10", "--tol", "1e-9", "--seed", "1"]
    host = decompose_mod.main(common + ["--engine", "host"])
    scan = decompose_mod.main(common + ["--engine", "scan", "--check-every", "4",
                                        "--json", str(path)])
    assert abs(scan["fit"] - host["fit"]) < 1e-5
    blob = json.loads(path.read_text())
    assert blob["engine"] == "scan" and blob["tol"] == 1e-9
    assert blob["iters"] == len(blob["fit_history"])
    assert blob["seconds_per_iter"] > 0
    assert blob["fit"] == pytest.approx(scan["fit"])
    # the unified driver schema (repro.launch.summary) rides along with the
    # historical top-level payload keys
    from repro.launch.summary import SCHEMA_VERSION
    assert blob["schema_version"] == SCHEMA_VERSION
    assert blob["kind"] == "decompose"
    ro = blob["resolved_options"]
    assert ro["engine"] == "scan" and ro["rank"] == 3
    assert ro["constraints"] == blob["constraints"]
    assert ro["compress"] == {"spec": "none"}


def test_decompose_constraint_roundtrips_through_json(tmp_path):
    """--constraint specs canonicalize into the --json summary's constraint
    block, and the l1 knob's observable effect (V sparsity) is reported."""
    import json

    path = tmp_path / "out.json"
    out = decompose_mod.main([
        "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
        "--iters", "6", "--constraint", "v=nonneg+l1:0.2,w=nonneg_admm",
        "--json", str(path),
    ])
    blob = json.loads(path.read_text())
    assert blob["constraints"] == {"h": "none", "v": "nonneg+l1:0.2",
                                   "w": "nonneg_admm"}
    assert blob["constraints"] == out["constraints"]
    assert 0.0 <= blob["v_zero_fraction"] <= 1.0
    assert np.isfinite(out["fit"])


def test_decompose_bare_constraint_applies_to_v_and_w(tmp_path):
    out = decompose_mod.main([
        "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
        "--iters", "4", "--constraint", "nonneg_admm",
    ])
    assert out["constraints"]["v"] == "nonneg_admm"
    assert out["constraints"]["w"] == "nonneg_admm"


def test_decompose_invalid_constraint_lists_registered():
    """A bad spec fails fast with an error naming every registered
    constraint (the user's discovery path)."""
    from repro.core.constraints import available

    with pytest.raises(ValueError) as ei:
        decompose_mod.main([
            "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
            "--iters", "2", "--constraint", "v=bogus",
        ])
    msg = str(ei.value)
    assert "registered constraints" in msg
    for name in available():
        assert name in msg
    with pytest.raises(ValueError, match="mode"):
        decompose_mod.main([
            "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
            "--iters", "2", "--constraint", "q=nonneg",
        ])


def test_decompose_compress_axis_roundtrips_through_json(tmp_path):
    """--compress routes the fit through the randomized-compression stage and
    the resolved spec (with its sketch geometry) lands in the summary."""
    import json

    path = tmp_path / "out.json"
    out = decompose_mod.main([
        "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
        "--iters", "8", "--compress", "rsvd:8:4:1", "--json", str(path),
    ])
    assert np.isfinite(out["fit"]) and 0.0 < out["fit"] <= 1.0
    blob = json.loads(path.read_text())
    assert blob["compress"] == "rsvd:8:4:1"
    assert blob["resolved_options"]["compress"] == {
        "spec": "rsvd:8:4:1", "sketch_dim": 12, "power_iters": 1}


def test_decompose_invalid_compress_lists_registered():
    from repro.core.compress import available

    with pytest.raises(ValueError) as ei:
        decompose_mod.main([
            "--dataset", "synthetic", "--scale", "0.003", "--rank", "3",
            "--iters", "2", "--compress", "bogus",
        ])
    msg = str(ei.value)
    assert "registered preprocessors" in msg
    for name in available():
        assert name in msg


def test_run_summary_rejects_schema_key_collisions():
    from repro.launch.summary import run_summary

    with pytest.raises(ValueError, match="collide"):
        run_summary("decompose", None, schema_version=99)
    blob = run_summary("dryrun", {"rank": 4}, fit=0.5)
    assert blob["kind"] == "dryrun" and blob["resolved_options"]["rank"] == 4
    assert blob["fit"] == 0.5


def test_sample_token_greedy_and_topk():
    rng = jax.random.PRNGKey(0)
    logits = jnp.asarray([[[0.1, 5.0, 0.2, 0.3]]], jnp.float32)
    greedy = sample_token(logits, rng, temperature=0.0)
    assert int(greedy[0, 0]) == 1
    # top-k=1 sampling always picks the argmax regardless of temperature
    for seed in range(5):
        t = sample_token(logits, jax.random.PRNGKey(seed), temperature=2.0, top_k=1)
        assert int(t[0, 0]) == 1
    # high temperature with full support eventually picks something else
    seen = {int(sample_token(logits, jax.random.PRNGKey(s), temperature=50.0)[0, 0])
            for s in range(50)}
    assert len(seen) > 1


# ---------------------------------------------------------------------------
# stream driver (launch/stream.py) — ISSUE-6 serving CLI
# ---------------------------------------------------------------------------

def test_stream_driver_json_summary(tmp_path):
    """--json writes the machine-readable latency/throughput/drift summary
    (the blob CI's stream bench gate and dashboards consume)."""
    import json
    from repro.launch import stream as stream_mod

    path = tmp_path / "stream.json"
    out = stream_mod.main([
        "--dataset", "synthetic", "--scale", "0.002", "--rank", "3",
        "--warm-iters", "5", "--warm-frac", "0.6", "--touch-frac", "0.3",
        "--batch-slots", "4", "--drift-threshold", "1e9",
        "--smooth", "0.1", "--format", "auto", "--seed", "0",
        "--json", str(path),
    ])
    blob = json.loads(path.read_text())
    assert blob["appends"] == out["appends"] > 0
    assert blob["batches"] >= 1
    assert blob["new"] + blob["touched"] == blob["appends"]
    for q in ("p50", "p99", "mean", "max"):
        assert blob["latency_ms"][q] > 0
    assert blob["latency_ms"]["p50"] <= blob["latency_ms"]["p99"]
    assert blob["subjects_per_s"] > 0
    assert 0.0 <= blob["drift"] and blob["refits"] == 0
    assert np.isfinite(blob["stream_fit"]) and np.isfinite(blob["baseline_fit"])
    assert blob["warm"]["fit"] == out["warm"]["fit"]
    assert blob["smooth_lam"] == 0.1
    assert blob["n_subjects"] > blob["warm"]["n_subjects"]  # stream grew K
    # the same unified schema block decompose.py stamps
    from repro.launch.summary import SCHEMA_VERSION
    assert blob["schema_version"] == SCHEMA_VERSION
    assert blob["kind"] == "stream"
    ro = blob["resolved_options"]
    assert ro["rank"] == 3 and ro["format"] == "auto"
    assert ro["constraints"] == blob["constraints"]
    assert ro["smooth_lam"] == 0.1


def test_stream_driver_replays_appends_file(tmp_path):
    """--appends FILE.jsonl replays external payloads; the summary counts
    exactly the replayed requests and a checkpoint lands in --ckpt-dir."""
    import json
    from repro.launch import stream as stream_mod
    from repro import checkpoint as ckpt

    appends = tmp_path / "appends.jsonl"
    payloads = [
        {"rows": [0, 1, 2], "cols": [0, 3, 5], "vals": [1.0, 2.0, 3.0],
         "n_rows": 4},
        {"rows": [0, 0, 1], "cols": [1, 2, 4], "vals": [0.5, 0.25, 4.0]},
    ]
    appends.write_text("\n".join(json.dumps(p) for p in payloads) + "\n")
    ckpt_dir = tmp_path / "ckpt"
    out = stream_mod.main([
        "--dataset", "synthetic", "--scale", "0.002", "--rank", "3",
        "--warm-iters", "4", "--drift-threshold", "1e9",
        "--appends", str(appends), "--ckpt-dir", str(ckpt_dir),
    ])
    assert out["appends"] == 2 and out["new"] == 2 and out["touched"] == 0
    assert ckpt.latest_step(str(ckpt_dir)) == 2


def test_stream_driver_fails_fast_on_malformed_payloads(tmp_path):
    """Malformed append payloads abort with ValueError BEFORE any dispatch:
    bad JSON, missing keys, and out-of-range columns all name the problem."""
    from repro.launch import stream as stream_mod

    base = ["--dataset", "synthetic", "--scale", "0.002", "--rank", "3",
            "--warm-iters", "3", "--drift-threshold", "1e9"]

    bad_json = tmp_path / "bad.jsonl"
    bad_json.write_text('{"rows": [0], "cols": [0]\n')
    with pytest.raises(ValueError, match="not valid JSON"):
        stream_mod.main(base + ["--appends", str(bad_json)])

    missing = tmp_path / "missing.jsonl"
    missing.write_text('{"rows": [0], "cols": [0]}\n')
    with pytest.raises(ValueError, match="missing required key"):
        stream_mod.main(base + ["--appends", str(missing)])

    out_of_range = tmp_path / "oob.jsonl"
    out_of_range.write_text(
        '{"rows": [0], "cols": [10000000], "vals": [1.0]}\n')
    with pytest.raises(ValueError, match="column ids"):
        stream_mod.main(base + ["--appends", str(out_of_range)])
