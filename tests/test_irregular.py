"""CC/BCC format correctness: round-trips, bucketing invariants."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.sparse import random_irregular, plan_buckets, from_dense_slices
from repro.core import bucketize, to_block_bucket, LANE


def test_cc_roundtrip_dense():
    data = random_irregular(n_subjects=8, n_cols=17, max_rows=9,
                            avg_nnz_per_subject=20, seed=0)
    bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
    seen = {}
    for b in bt.buckets:
        dense = b.scatter_cols_to_dense(jnp.transpose(b.vals, (0, 2, 1)).transpose(0, 2, 1), data.n_cols)
        # vals [Kb, I, C] -> dense [Kb, I, J]
        dense = b.scatter_cols_to_dense(b.vals, data.n_cols)
        for slot in range(b.kb):
            if float(b.subject_mask[slot]) > 0:
                k = int(b.subject_ids[slot])
                seen[k] = np.asarray(dense[slot, : int(b.row_counts[slot]), :])
    assert len(seen) == data.n_subjects
    for k, sub in enumerate(data.subjects):
        np.testing.assert_allclose(seen[k], sub.to_dense(), atol=1e-12)


def test_bucket_plan_partition():
    rc = [3, 5, 9, 2, 14, 7, 7]
    cc = [4, 4, 8, 2, 16, 8, 4]
    plan = plan_buckets(rc, cc, max_buckets=3, row_align=4, col_align=4)
    all_members = np.concatenate(plan.members)
    assert sorted(all_members.tolist()) == list(range(7))
    for (ip, cp), mem in zip(plan.shapes, plan.members):
        assert ip % 4 == 0 and cp % 4 == 0
        for k in mem:
            assert rc[k] <= ip and cc[k] <= cp


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 30),
    max_buckets=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_property_bucket_partition(n, max_buckets, seed):
    rng = np.random.default_rng(seed)
    rc = rng.integers(1, 50, n)
    cc = rng.integers(1, 30, n)
    plan = plan_buckets(rc, cc, max_buckets=max_buckets)
    members = np.concatenate(plan.members)
    assert sorted(members.tolist()) == list(range(n))
    waste = plan.padding_waste(rc, cc)
    assert 0.0 <= waste < 1.0


def test_bcc_matches_cc_product():
    """BCC X_k V must equal CC X_k V (the kernel-format conversion is lossless
    when max_blocks is not truncating)."""
    data = random_irregular(n_subjects=6, n_cols=300, max_rows=10,
                            avg_nnz_per_subject=40, seed=2)
    bt = bucketize(data, max_buckets=1, dtype=jnp.float64)
    b = bt.buckets[0]
    R = 4
    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.standard_normal((data.n_cols, R)))
    ref = b.xk_times_v(V)
    bb = to_block_bucket(b, data.n_cols)
    # BCC product: sum over blocks of vals[k,:,b,:] @ V[blk*LANE:(blk+1)*LANE]
    J_pad = ((data.n_cols + LANE - 1) // LANE) * LANE
    V_pad = jnp.zeros((J_pad, R), V.dtype).at[: data.n_cols].set(V)
    V_blocks = V_pad.reshape(-1, LANE, R)
    Vg = V_blocks[bb.blk_ids] * bb.blk_mask[..., None, None]   # [Kb, NB, LANE, R]
    out = jnp.einsum("kinl,knlr->kir", bb.vals, Vg)
    np.testing.assert_allclose(out, ref, atol=1e-10)


def test_from_dense_slices():
    rng = np.random.default_rng(5)
    slices = [rng.random((4, 6)) * (rng.random((4, 6)) < 0.5) for _ in range(3)]
    data = from_dense_slices(slices)
    for s, X in zip(data.subjects, slices):
        np.testing.assert_allclose(s.to_dense(), X)


def test_to_block_bucket_truncation_raises():
    """max_blocks truncation drops nonzeros -> loud ValueError with the
    dropped count by default; allow_truncate=True downgrades it to a
    warning (the old behaviour was SILENT data loss)."""
    # two subjects whose columns span 3 distinct LANE blocks each
    data = random_irregular(n_subjects=2, n_cols=3 * LANE, max_rows=4,
                            avg_nnz_per_subject=30, seed=9)
    bt = bucketize(data, max_buckets=1, dtype=jnp.float64)
    b = bt.buckets[0]
    # untruncated conversion is clean (no exception, no warning)
    to_block_bucket(b, data.n_cols)
    with pytest.raises(ValueError, match=r"truncated \d+ nonzeros"):
        to_block_bucket(b, data.n_cols, max_blocks=1)
    with pytest.warns(UserWarning, match=r"truncated \d+ nonzeros"):
        bb = to_block_bucket(b, data.n_cols, max_blocks=1, allow_truncate=True)
    assert bb.vals.shape[2] == 1   # the cap was applied


def test_bucketize_dtype_sweep():
    """Staging-buffer dtype: f64 only for f64 requests; bf16/f16 stage in
    f32 and cast once (the old check silently staged them in f64). Output
    dtypes and values must match the f32-staged reference for every float."""
    from repro.core.irregular import _staging_dtype

    assert _staging_dtype(jnp.float64) == np.float64
    for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
        assert _staging_dtype(dt) == np.float32

    data = random_irregular(n_subjects=6, n_cols=23, max_rows=7,
                            avg_nnz_per_subject=14, seed=4)
    # one shared plan so cc/scoo buckets align with the reference
    plan = plan_buckets(data.row_counts(), data.col_counts(),
                        nnz_counts=data.nnz_counts(), max_buckets=2,
                        col_align=4)
    ref = bucketize(data, dtype=jnp.float32, plan=plan)
    for fmt in ("cc", "scoo"):
        for dt in (jnp.float16, jnp.bfloat16, jnp.float32, jnp.float64):
            bt = bucketize(data, dtype=dt, plan=plan,
                           formats=[fmt] * plan.n_buckets)
            for b, rb in zip(bt.buckets, ref.buckets):
                assert b.vals.dtype == jnp.dtype(dt)
                assert b.col_mask.dtype == jnp.dtype(dt)
                # values survive the round-trip at the dtype's precision
                dense = (b.vals if fmt == "cc"
                         else b.dense_vals()).astype(jnp.float64)
                ref_vals = np.asarray(rb.vals, dtype=np.float64)
                # the reference itself is f32, so never expect better than f32
                tol = max(float(jnp.finfo(dt).eps),
                          float(jnp.finfo(jnp.float32).eps))
                np.testing.assert_allclose(
                    np.asarray(dense), ref_vals,
                    rtol=2 * tol, atol=2 * tol * max(abs(ref_vals).max(), 1))
