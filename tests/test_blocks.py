"""Unit tests for sequence-mixing blocks: chunked SSD, flash attention,
RG-LRU associative scan, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_reference
from repro.models.attention import attend_train, attend_decode
from repro.models.rglru import rglru_scan
from repro.models.moe import moe_block, init_moe
from repro.configs import get_config, reduced


# ---------------------------------------------------------------------------
# SSD: chunked == sequential reference (the state-space duality identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(16, 4), (17, 4), (32, 8), (8, 16)])
def test_ssd_chunked_matches_reference(S, chunk):
    rng = np.random.default_rng(0)
    B, H, P, N = 2, 3, 4, 5
    xdt = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.7, 0.999, (B, S, H)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    want = ssd_reference(xdt, a, Bm, Cm)
    got, h_fin = ssd_chunked(xdt, a, Bm, Cm, chunk)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # final state matches a full sequential rollout's final state
    hs = np.zeros((B, H, P, N), np.float32)
    for t in range(S):
        hs = np.asarray(a)[:, t, :, None, None] * hs + \
            np.asarray(xdt)[:, t, :, :, None] * np.asarray(Bm)[:, t, None, None, :]
    np.testing.assert_allclose(h_fin, hs, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), S=st.integers(2, 24), chunk=st.integers(2, 8))
def test_property_ssd_duality(seed, S, chunk):
    rng = np.random.default_rng(seed)
    B, H, P, N = 1, 2, 3, 4
    xdt = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (B, S, H)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    got, _ = ssd_chunked(xdt, a, Bm, Cm, chunk)
    want = ssd_reference(xdt, a, Bm, Cm)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Attention: chunked online-softmax == naive
# ---------------------------------------------------------------------------

def _naive_attn(q, k, v, causal=True, window=0):
    B, S, H, D = q.shape
    KV = k.shape[2]
    groups = H // KV
    k = np.repeat(np.asarray(k), groups, axis=2)
    v = np.repeat(np.asarray(v), groups, axis=2)
    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q), k) / np.sqrt(D)
    i, j = np.arange(S)[:, None], np.arange(S)[None, :]
    mask = np.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bhqk,bkhd->bqhd", p, v)
    return out


@pytest.mark.parametrize("S,block,window", [(16, 8, 0), (33, 8, 0), (32, 8, 8), (16, 32, 4)])
def test_flash_attention_matches_naive(S, block, window):
    rng = np.random.default_rng(1)
    B, H, KV, D = 2, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    got = attend_train(q, k, v, causal=True, window=window, block_kv=block)
    want = _naive_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_naive_last_position():
    rng = np.random.default_rng(2)
    B, S, H, KV, D = 2, 12, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    full = attend_train(q, k, v, causal=True)
    dec = attend_decode(q[:, -1:], k, v, length=jnp.full((B,), S))
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU associative scan == sequential recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [1, 7, 32])
def test_rglru_scan_matches_sequential(S):
    rng = np.random.default_rng(3)
    B, W = 2, 5
    a = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, W)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, W)), jnp.float32)
    got = rglru_scan(a, b, h0)
    h = np.asarray(h0)
    seq = []
    for t in range(S):
        h = np.asarray(a)[:, t] * h + np.asarray(b)[:, t]
        seq.append(h.copy())
    np.testing.assert_allclose(got, np.stack(seq, 1), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: output finite, gates normalized, capacity drops bounded
# ---------------------------------------------------------------------------

def test_moe_block_basic():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    rng = jax.random.PRNGKey(0)
    p = init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # Switch aux loss is >= 1 (equals 1 at perfect balance) and finite
    assert 0.9 <= float(aux) < float(cfg.n_experts)


def test_moe_capacity_sufficient_identity():
    """With capacity >= T*k (no drops) and experts identical, the MoE must act
    like a single dense MLP (combine weights sum to 1)."""
    import dataclasses
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    rng = jax.random.PRNGKey(1)
    p = init_moe(rng, cfg, jnp.float32)
    # make every expert identical
    for k in ("w_gate", "w_up", "w_down"):
        w = p["experts"][k]
        p["experts"][k] = jnp.broadcast_to(w[:1], w.shape)
    x = jax.random.normal(rng, (1, 8, cfg.d_model), jnp.float32)
    y, _ = moe_block(p, x, cfg)
    from repro.models.mlp import mlp_block
    dense = {"w_gate": p["experts"]["w_gate"][0], "w_up": p["experts"]["w_up"][0],
             "w_down": p["experts"]["w_down"][0]}
    want = mlp_block(dense, x, cfg)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
