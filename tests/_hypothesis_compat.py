"""`hypothesis` when installed; otherwise a deterministic seeded fallback.

The container the tier-1 suite runs in does not ship `hypothesis`, and
installing packages is off-limits. The property tests only use
``@settings(max_examples=N, deadline=None)`` + ``@given(x=st.integers(a, b))``,
so the fallback replays each property on `max_examples` draws from a fixed
PRNG — weaker than real hypothesis (no shrinking, no example database) but
the same assertions on the same kind of input distribution.

Usage in test modules: ``from _hypothesis_compat import given, settings, st``.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import random

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rng):
            return rng.randint(self.min_value, self.max_value)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    def settings(*, max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must NOT see the
            # property's parameters, or it would treat them as fixtures)
            def wrapper():
                rng = random.Random(1234)
                for _ in range(getattr(wrapper, "_max_examples", 20)):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
