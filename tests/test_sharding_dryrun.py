"""Sharding rules + dry-run machinery tests.

The multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (a miniature of the
512-device production dry-run) so the main test process keeps 1 device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    LM_RULES, axis_rules, enforce_divisible, logical_spec, param_spec)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_logical_spec_no_rules_is_empty():
    assert logical_spec(("batch", "seq")) == P()


def test_logical_spec_drops_missing_pod_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with axis_rules(LM_RULES, mesh):
        spec = logical_spec(("batch", "seq", "heads"), mesh)
    # batch -> ("pod","data") but mesh has no "pod": reduced to "data"
    assert spec == P("data", None, "model")


def test_enforce_divisible_replicates_uneven():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake 16-wide axes by building the spec directly
    spec = P("data", "model")
    out = enforce_divisible(spec, (7, 8), mesh)   # axes are size 1 -> fine
    assert out == P("data", "model")


def test_param_spec_paths():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with axis_rules(LM_RULES, mesh):
        # attention projections: last dim on model
        assert param_spec("layers/groups/p0_attn_mlp/attn/wq", 2, stacked=False)[-1] == "model"
        # stacked scan params: leading layer dim never sharded
        s = param_spec("layers/groups/p0_attn_mlp/attn/wq", 3, stacked=True)
        assert s[0] is None
        # optimizer prefix still matches
        s2 = param_spec("m/layers/groups/p0_attn_mlp/mlp/w_down", 3, stacked=True)
        assert s2[0] is None
        # norm scales replicated
        assert param_spec("layers/groups/p0_attn_mlp/ln1_scale", 1) == P()
        # embeddings: vocab on model
        assert param_spec("embed/tokens", 2)[0] == "model"


_SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, reduced, ShapeSpec
    from repro.dist.sharding import LM_RULES, axis_rules, param_shardings
    from repro.models import build
    from repro.analysis.hlo import collective_bytes
    from repro.analysis.roofline import roofline_terms

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    out = {}
    for arch in ["qwen3-0.6b", "mamba2-780m", "phi3.5-moe-42b-a6.6b"]:
        cfg = reduced(get_config(arch), d_model=64, n_heads=4, n_kv_heads=2,
                      vocab_size=256)
        bundle = build(cfg)
        with axis_rules(LM_RULES, mesh), mesh:
            pshapes = jax.eval_shape(bundle.init_params,
                                     jax.ShapeDtypeStruct((2,), jnp.uint32))
            p_sh = param_shardings(pshapes, mesh)
            oshapes = jax.eval_shape(bundle.init_opt, pshapes)
            o_sh = param_shardings(oshapes, mesh)
            sds = jax.ShapeDtypeStruct
            batch = {"tokens": sds((8, 16), jnp.int32),
                     "labels": sds((8, 16), jnp.int32)}
            from jax.sharding import NamedSharding, PartitionSpec as P
            b_sh = {k: NamedSharding(mesh, P(("pod", "data"))) for k in batch}
            lowered = jax.jit(bundle.train_step,
                              in_shardings=(p_sh, o_sh, b_sh, None),
                              out_shardings=(p_sh, o_sh, None)).lower(
                pshapes, oshapes, batch, sds((), jnp.int32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            terms = roofline_terms(compiled)
            out[arch] = {
                "arg_bytes": int(mem.argument_size_in_bytes),
                "collective_bytes": terms["collective_bytes"],
                "flops": terms["hlo_flops"],
            }
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_subprocess_multidevice_lower_compile():
    """Miniature production dry-run: 8 placeholder devices, (2,2,2) pod mesh,
    three families lower + compile with sharded params/opt/batch, and the
    roofline machinery extracts nonzero flops and collective bytes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SRC],
                          capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for arch, rec in out.items():
        assert rec["arg_bytes"] > 0, arch
        assert rec["flops"] > 0, arch
        # DP grad sync means at least one collective must appear
        assert rec["collective_bytes"] > 0, arch


def test_elastic_checkpoint_restore_onto_mesh(tmp_path):
    """Checkpoints store global arrays; restore re-shards them onto whatever
    mesh the new job runs (elastic resume). 4-device subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import checkpoint as ckpt

        tree = {{"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones((4,))}}
        ckpt.save({str(tmp_path)!r}, 3, tree)

        mesh = jax.make_mesh((4,), ("data",))
        shardings = {{"w": NamedSharding(mesh, P("data")),
                      "b": NamedSharding(mesh, P())}}
        restored, step, _ = ckpt.restore({str(tmp_path)!r}, tree,
                                         shardings=shardings)
        assert step == 3
        assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)
        np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout


def test_hlo_collective_parser_on_psum():
    """Parser sanity on a real compiled module containing an all-reduce."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import collective_bytes
        mesh = jax.make_mesh((4,), ("data",))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        def f(a):
            return jax.lax.with_sharding_constraint(
                a.sum() * jnp.ones_like(a), NamedSharding(mesh, P()))
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))).lower(x).compile()
        cb = collective_bytes(c.as_text())
        print(cb["total"])
    """)
    proc = subprocess.run([sys.executable, "-c", src],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    total = float(proc.stdout.strip().splitlines()[-1])
    assert total > 0
