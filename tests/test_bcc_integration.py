"""BCC Pallas kernel integrated against real bucketized data: the kernel-
format X_k V must equal the CC einsum path on every bucket."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bucketize, to_block_bucket
from repro.sparse import random_irregular


@pytest.mark.parametrize("seed,J,R", [(0, 300, 8), (1, 500, 16), (2, 130, 4)])
def test_bcc_kernel_matches_cc(seed, J, R):
    data = random_irregular(n_subjects=9, n_cols=J, max_rows=12,
                            avg_nnz_per_subject=40, seed=seed)
    bt = bucketize(data, max_buckets=2, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    for b in bt.buckets:
        bcc = to_block_bucket(b, J)
        ref = b.xk_times_v(V)
        got = b.xk_times_v_bcc(bcc, V)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), J=st.integers(10, 400), R=st.integers(1, 12))
def test_property_bcc_kernel_matches_cc(seed, J, R):
    data = random_irregular(n_subjects=4, n_cols=J, max_rows=6,
                            avg_nnz_per_subject=15, seed=seed)
    bt = bucketize(data, max_buckets=1, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    V = jnp.asarray(rng.standard_normal((J, R)), jnp.float32)
    b = bt.buckets[0]
    bcc = to_block_bucket(b, J)
    np.testing.assert_allclose(b.xk_times_v_bcc(bcc, V), b.xk_times_v(V),
                               rtol=1e-4, atol=1e-3)
