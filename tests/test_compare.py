"""benchmarks/compare.py gate semantics: renames and new namespaces must
never crash the gate — they skip with a notice; real regressions still trip."""
import json

import pytest

from benchmarks import compare as cmp


def _ns(rows):
    """A namespace whose leaves are {case: {seconds_per_iter: v}}."""
    return {case: {"seconds_per_iter": v} for case, v in rows.items()}


def test_identical_runs_pass():
    base = _ns({"a/host": 1.0, "b/host": 2.0, "c/host": 3.0})
    regs, rows = cmp.compare_namespace("als", base, base, threshold=1.5)
    assert regs == []
    assert all(flag != "REGRESSED" for _, _, flag in rows)


def test_real_regression_trips():
    base = _ns({"a/host": 1.0, "b/host": 1.0, "c/host": 1.0, "d/host": 1.0})
    cur = _ns({"a/host": 1.0, "b/host": 1.0, "c/host": 1.0, "d/host": 10.0})
    regs, _ = cmp.compare_namespace("als", base, cur, threshold=1.5)
    assert len(regs) == 1 and "d/host" in regs[0]


def test_axis_rename_skips_with_one_notice():
    """A leaf present only in current (axis rename / grown grid) is reported
    as ONE 'new leaf, ungated' line — not gated, not a KeyError, not a wall
    of per-row noise."""
    base = _ns({"a/host/nonneg": 1.0, "b/host/nonneg": 1.0,
                "c/host/nonneg": 1.0})
    cur = _ns({"a/host/nonneg": 1.0, "b/host/nonneg": 1.0,
               "c/host/nonneg": 1.0,
               "a/host/nonneg/rsvd": 0.2, "b/host/nonneg/rsvd": 0.2})
    regs, rows = cmp.compare_namespace("als", base, cur, threshold=1.5)
    assert regs == []
    notices = [r for r in rows if "new leaf" in r[0]]
    assert len(notices) == 1 and "2 new leaf" in notices[0][0]
    # and the reverse direction (row gone from current) stays non-fatal
    regs, rows = cmp.compare_namespace("als", cur, base, threshold=1.5)
    assert regs == []
    assert sum("MISSING in current" in v for _, v, _ in rows) == 2


def test_new_namespace_is_ungated(tmp_path, capsys):
    """--current naming a namespace absent from the baseline (or present as
    a non-dict stub) skips gracefully with exit code 0."""
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(
        {"als": _ns({"a/host": 1.0}), "als_rsvd": "placeholder"}))
    cur1 = tmp_path / "c1.json"
    cur1.write_text(json.dumps(_ns({"a/host/rsvd": 0.5})))
    cur2 = tmp_path / "c2.json"
    cur2.write_text(json.dumps(_ns({"a/host/rsvd": 0.5})))
    rc = cmp.main(["--baseline", str(baseline),
                   "--current", f"brand_new={cur1}",
                   "--current", f"als_rsvd={cur2}"])
    assert rc == 0
    out = capsys.readouterr().out
    assert out.count("new namespace, ungated") == 2


def test_speedup_leaves_gate_without_normalization():
    base = {"x": {"speedup_vs_uncompressed_per_iter": 4.0}}
    good = {"x": {"speedup_vs_uncompressed_per_iter": 3.5}}
    bad = {"x": {"speedup_vs_uncompressed_per_iter": 1.5}}
    regs, _ = cmp.compare_namespace("als_rsvd", base, good, threshold=1.5)
    assert regs == []
    regs, _ = cmp.compare_namespace("als_rsvd", base, bad, threshold=1.5)
    assert len(regs) == 1


def test_skip_substring_exempts_but_reports():
    base = _ns({"a/pallas": 1.0, "a/host": 1.0, "b/host": 1.0, "c/host": 1.0})
    cur = _ns({"a/pallas": 50.0, "a/host": 1.0, "b/host": 1.0, "c/host": 1.0})
    regs, rows = cmp.compare_namespace("als", base, cur, threshold=1.5,
                                       skip=("/pallas",))
    assert regs == []
    assert any(flag == "skipped (not gated)" for _, _, flag in rows)
