"""Training-feature tests: gradient-accumulation microbatching and the
local-attention ring-buffer decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build


def test_microbatch_equivalence():
    """n microbatches must produce the same update as one full batch
    (f32 grad accumulation; AdamW sees the averaged gradient)."""
    cfg = reduced(get_config("qwen3-0.6b"))
    b1 = build(cfg, microbatches=1)
    b4 = build(cfg, microbatches=4)
    rng = jax.random.PRNGKey(0)
    params = b1.init_params(rng)
    opt = b1.init_opt(params)
    tokens = jax.random.randint(rng, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1).at[:, -1].set(-1)}
    p1, _, m1 = jax.jit(b1.train_step)(params, opt, batch, 0)
    p4, _, m4 = jax.jit(b4.train_step)(params, opt, batch, 0)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_microbatch_moe_arch():
    """Accumulation composes with MoE blocks (aux loss averaged)."""
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    b2 = build(cfg, microbatches=2)
    rng = jax.random.PRNGKey(1)
    params = b2.init_params(rng)
    opt = b2.init_opt(params)
    tokens = jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1).at[:, -1].set(-1)}
    _, _, m = jax.jit(b2.train_step)(params, opt, batch, 0)
    assert np.isfinite(float(m["loss"]))
    assert float(m["aux"]) > 0


def test_local_attention_ring_buffer_decode():
    """Decoding past the window: ring-buffer decode logits must match a
    prefill over the same prefix (window truncation applied identically)."""
    cfg = reduced(get_config("recurrentgemma-9b"))
    # pattern ("rglru","rglru","attn_local"); window = 16 in reduced config
    bundle = build(cfg)
    rng = jax.random.PRNGKey(2)
    params = bundle.init_params(rng)
    B, S = 2, 24  # S > window (16): the ring wraps
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = bundle.prefill_step(params, {"tokens": tokens})
    cache = bundle.init_cache(B, S)
    step = jax.jit(bundle.decode_step)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.asarray(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, t], np.float32),
            rtol=3e-2, atol=3e-2)
