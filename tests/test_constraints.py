"""Constraint-layer suite: spec parsing/registry, prox operators, AO-ADMM vs
HALS agreement, l1 sparsity / smooth TV behaviour, engine parity with ADMM
aux state in the carry, and the removed ``nonneg`` flag's fail-fast
TypeError with its migration hint."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import bucketize, Parafac2Options, als_step, fit, init_state
from repro.core import constraints as cst
from repro.core.nnls import hals_nnls
from repro.core.parafac2 import constraints_for
from repro.data import choa_like
from repro.sparse import random_parafac2

f64 = jnp.float64


@pytest.fixture(scope="module")
def choa_bt():
    data = choa_like(scale=5e-5, seed=0)
    return bucketize(data, max_buckets=2, dtype=f64)


@pytest.fixture(scope="module")
def exact_bt():
    data, _ = random_parafac2(n_subjects=20, n_cols=30, max_rows=25, rank=4,
                              density=1.0, seed=1)
    return bucketize(data, max_buckets=2, dtype=f64)


# ---------------------------------------------------------------------------
# spec parsing + registry
# ---------------------------------------------------------------------------

def test_parse_spec_canonicalizes():
    c = cst.parse_spec("nonneg + l1")
    assert c.spec == "nonneg+l1:0.1"          # default lam filled in
    assert c.solver == "admm" and c.nonneg
    assert cst.parse_spec("l1:0.25").terms == (("l1", 0.25),)
    assert cst.parse_spec("none").solver == "ridge"
    assert cst.parse_spec("nonneg").solver == "hals"
    assert cst.parse_spec("nonneg_admm").solver == "admm"
    assert cst.parse_spec("").spec == "none"


def test_parse_spec_unknown_lists_registered():
    with pytest.raises(ValueError, match="registered constraints"):
        cst.parse_spec("bogus")
    with pytest.raises(ValueError) as ei:
        cst.parse_spec("nonneg+bogus:3")
    for name in cst.available():
        assert name in str(ei.value)


def test_parse_spec_rejects_bad_compositions():
    with pytest.raises(ValueError, match="smooth"):
        cst.parse_spec("smooth+nonneg")
    with pytest.raises(ValueError, match="strength"):
        cst.parse_spec("l1:abc")
    with pytest.raises(ValueError, match="negative"):
        cst.parse_spec("l1:-1")
    # indicator terms have no strength knob: 'nonneg:1' would otherwise
    # silently flip the penalized flag without applying any penalty
    with pytest.raises(ValueError, match="indicator"):
        cst.parse_spec("nonneg:1")
    with pytest.raises(ValueError, match="indicator"):
        cst.parse_spec("none:5")


def test_penalized_flag_only_for_penalty_terms():
    assert not cst.parse_spec("nonneg").penalized
    assert not cst.parse_spec("nonneg_admm").penalized
    assert cst.parse_spec("l1:0.1").penalized
    assert cst.parse_spec("smooth:0.1").penalized
    assert cst.parse_spec("nonneg+l1:0.1").penalized
    assert not cst.parse_spec("l1:0").penalized   # zero-strength == indicator


def test_parse_constraint_arg_modes_and_bare_spec():
    d = cst.parse_constraint_arg("v=nonneg+l1:0.1,w=smooth:0.5")
    assert d == {"v": "nonneg+l1:0.1", "w": "smooth:0.5"}
    # bare spec applies to V and W
    assert cst.parse_constraint_arg("nonneg_admm") == {
        "v": "nonneg_admm", "w": "nonneg_admm"}
    with pytest.raises(ValueError, match="mode"):
        cst.parse_constraint_arg("q=nonneg")
    with pytest.raises(ValueError, match="registered constraints"):
        cst.parse_constraint_arg("v=typo")


def test_register_custom_term():
    cst.register_term("clip2", cst.TermDef(
        kind="custom", solver="admm",
        prox=lambda Y, rho, lam: jnp.clip(Y, 0.0, 2.0), nonneg=True))
    try:
        c = cst.parse_spec("clip2")
        Z = c.prox(jnp.asarray([[-1.0, 5.0]]), 1.0)
        np.testing.assert_allclose(np.asarray(Z), [[0.0, 2.0]])
        with pytest.raises(ValueError, match="compose"):
            cst.parse_spec("clip2+l1")
    finally:
        cst._REGISTRY.pop("clip2", None)
        cst.parse_spec.cache_clear()


# ---------------------------------------------------------------------------
# prox operators
# ---------------------------------------------------------------------------

def test_prox_l1_soft_threshold():
    Y = jnp.asarray([-2.0, -0.05, 0.0, 0.05, 2.0], f64)
    Z = np.asarray(cst.prox_l1(Y, 0.1))
    np.testing.assert_allclose(Z, [-1.9, 0.0, 0.0, 0.0, 1.9], atol=1e-12)


def test_prox_nonneg_l1_shrink_then_clip():
    Y = jnp.asarray([-2.0, 0.05, 2.0], f64)
    np.testing.assert_allclose(
        np.asarray(cst.prox_nonneg_l1(Y, 0.1)), [0.0, 0.0, 1.9], atol=1e-12)


def test_prox_smooth_optimality():
    """Z = prox_smooth(Y) satisfies (rho I + 2 lam D^T D) Z = rho Y."""
    rng = np.random.default_rng(0)
    K, R, rho, lam = 9, 3, 0.7, 0.4
    Y = jnp.asarray(rng.standard_normal((K, R)))
    Z = np.asarray(cst.prox_smooth(Y, rho, lam))
    D = np.zeros((K - 1, K))
    D[np.arange(K - 1), np.arange(K - 1)] = -1.0
    D[np.arange(K - 1), np.arange(1, K)] = 1.0
    lhs = rho * Z + 2.0 * lam * (D.T @ D) @ Z
    np.testing.assert_allclose(lhs, rho * np.asarray(Y), atol=1e-10)
    # K=1: no differences to penalize — identity
    y1 = jnp.ones((1, 4), f64)
    np.testing.assert_array_equal(np.asarray(cst.prox_smooth(y1, 1.0, 5.0)),
                                  np.asarray(y1))


# ---------------------------------------------------------------------------
# hals_nnls vs a brute-force projected-gradient reference (satellite)
# ---------------------------------------------------------------------------

def _nnls_reference(M, A, iters=20000):
    """Projected gradient on  min_{X>=0} 0.5 tr(X A X^T) - tr(X M^T)."""
    X = np.maximum(M @ np.linalg.inv(A), 0.0)
    eta = 1.0 / np.linalg.norm(A, 2)
    for _ in range(iters):
        X = np.maximum(X - eta * (X @ A - M), 0.0)
    return X


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_hals_nnls_matches_projected_gradient(seed):
    rng = np.random.default_rng(seed)
    N, R = 30, 5
    G = rng.random((50, R)) + 0.1          # well-conditioned Gram
    A = G.T @ G
    T = rng.standard_normal((N, 50))
    M = T @ G
    ref = _nnls_reference(M, A)
    out = np.asarray(hals_nnls(jnp.asarray(M), jnp.asarray(A),
                               jnp.asarray(np.abs(rng.standard_normal((N, R)))),
                               sweeps=400))
    np.testing.assert_allclose(out, ref, atol=1e-6)
    assert (out >= 0).all()


def test_hals_nnls_eps_diag_guard():
    """A zero column in the Gram (dead factor) must not produce NaN/inf: the
    eps clamp on diag(A) keeps the division finite and the column at 0."""
    rng = np.random.default_rng(3)
    R = 4
    G = rng.random((20, R))
    G[:, 2] = 0.0                          # dead factor -> A[2,2] == 0
    A = jnp.asarray(G.T @ G, f64)
    M = jnp.asarray(rng.standard_normal((10, 20)) @ G, f64)
    X = np.asarray(hals_nnls(M, A, jnp.ones((10, R), f64), sweeps=10))
    assert np.isfinite(X).all()
    np.testing.assert_array_equal(X[:, 2], 0.0)


# ---------------------------------------------------------------------------
# AO-ADMM solver
# ---------------------------------------------------------------------------

def test_admm_nonneg_agrees_with_hals_1e6_f64():
    """Same strictly convex NNLS problem, two solvers, one minimizer: the
    warm-started ADMM route must land on the HALS solution to 1e-6."""
    rng = np.random.default_rng(7)
    N, R = 40, 5
    G = rng.random((60, R)) + 0.1
    A = jnp.asarray(G.T @ G, f64)
    M = jnp.asarray(rng.standard_normal((N, 60)) @ G, f64)
    X0 = jnp.asarray(np.abs(rng.standard_normal((N, R))), f64)
    x_hals = np.asarray(hals_nnls(M, A, X0, sweeps=500))
    c = cst.parse_spec("nonneg_admm")
    x_admm, aux = c.update(M, A, X0, (), admm_iters=50)
    for _ in range(20):                     # warm-started outer refreshes
        x_admm, aux = c.update(M, A, x_admm, aux, admm_iters=50)
    np.testing.assert_allclose(np.asarray(x_admm), x_hals, atol=1e-6)


def test_admm_l1_sparsifies_vs_lam():
    """Standalone l1 solve: zero fraction is monotone in lambda."""
    rng = np.random.default_rng(11)
    R = 5
    G = rng.random((60, R)) + 0.1
    A = jnp.asarray(G.T @ G, f64)
    M = jnp.asarray(rng.standard_normal((30, 60)) @ G, f64)
    zero_fracs = []
    for lam in (0.0, 1.0, 10.0, 100.0):
        c = cst.parse_spec(f"l1:{lam}")
        X, aux = c.update(M, A, jnp.zeros((30, R), f64), (), admm_iters=200)
        zero_fracs.append(float((np.asarray(X) == 0.0).mean()))
    assert zero_fracs == sorted(zero_fracs), zero_fracs
    assert zero_fracs[-1] > zero_fracs[0]


# ---------------------------------------------------------------------------
# end-to-end fits
# ---------------------------------------------------------------------------

def test_fit_nonneg_admm_close_to_hals(exact_bt):
    kw = dict(rank=4, dtype=f64)
    _, hh = fit(exact_bt, Parafac2Options(
        constraints={"v": "nonneg", "w": "nonneg"}, **kw), max_iters=40, tol=0.0)
    st, ha = fit(exact_bt, Parafac2Options(
        constraints={"v": "nonneg_admm", "w": "nonneg_admm"}, admm_iters=20,
        **kw), max_iters=40, tol=0.0)
    assert abs(ha[-1] - hh[-1]) < 1e-2      # same model, same quality
    assert (np.asarray(st.V) >= 0).all() and (np.asarray(st.W) >= 0).all()
    # the ADMM duals rode in the state and are structurally live
    assert st.aux["v"] != () and st.aux["w"] != ()


def test_fit_l1_drives_v_sparsity_monotone(exact_bt):
    fracs = []
    for lam in (0.0, 1.0, 5.0, 20.0):
        spec = "nonneg" if lam == 0.0 else f"nonneg+l1:{lam}"
        st, _ = fit(exact_bt, Parafac2Options(
            rank=4, constraints={"v": spec, "w": "nonneg"}, dtype=f64),
            max_iters=30, tol=0.0)
        fracs.append(float((np.asarray(st.V) == 0.0).mean()))
    assert fracs == sorted(fracs), fracs
    assert fracs[-1] > fracs[0] + 0.3, fracs


def _total_variation(W):
    return float(np.abs(np.diff(np.asarray(W), axis=0)).sum())


def test_fit_smooth_reduces_w_total_variation(choa_bt):
    kw = dict(rank=3, dtype=f64)
    st0, _ = fit(choa_bt, Parafac2Options(
        constraints={"v": "nonneg", "w": "none"}, **kw), max_iters=20, tol=0.0)
    st1, _ = fit(choa_bt, Parafac2Options(
        constraints={"v": "nonneg", "w": "smooth:1.0"}, **kw),
        max_iters=20, tol=0.0)
    assert _total_variation(st1.W) < _total_variation(st0.W)


def test_smooth_needs_global_w_layout(choa_bt):
    opts = Parafac2Options(rank=3, constraints={"w": "smooth:0.1"},
                           w_layout="bucketed")
    with pytest.raises(ValueError, match="w_layout"):
        init_state(choa_bt, opts, seed=0)


# ---------------------------------------------------------------------------
# engine parity with ADMM aux state in the carry
# ---------------------------------------------------------------------------

ADMM_SPECS = {"v": "nonneg_admm", "w": "nonneg_admm"}


def _traj(bt, engine, specs, *, check_every=4, iters=10, w_layout="global"):
    opts = Parafac2Options(rank=3, constraints=specs, dtype=f64,
                           engine=engine, check_every=check_every,
                           w_layout=w_layout)
    state, hist = fit(bt, opts, max_iters=iters, tol=0.0, seed=0)
    return state, np.asarray(hist)


def test_admm_scan_matches_host_bitwise(choa_bt):
    _, hh = _traj(choa_bt, "host", ADMM_SPECS)
    _, hs = _traj(choa_bt, "scan", ADMM_SPECS, check_every=4)
    np.testing.assert_allclose(hs, hh, rtol=0, atol=1e-12)


def test_admm_while_matches_host_bitwise(choa_bt):
    _, hh = _traj(choa_bt, "host", ADMM_SPECS)
    _, hw = _traj(choa_bt, "scan", ADMM_SPECS, check_every=0)
    np.testing.assert_allclose(hw, hh, rtol=0, atol=1e-12)


def test_admm_mesh_matches_host(choa_bt):
    _, hh = _traj(choa_bt, "host", ADMM_SPECS)
    _, hm = _traj(choa_bt, "mesh", ADMM_SPECS, check_every=4)
    np.testing.assert_allclose(hm, hh, rtol=0, atol=1e-8)


def test_admm_mesh_bucketed_w_aux_sharded(choa_bt):
    """Bucketed-W ADMM: per-bucket dual state rides the subject shards."""
    sh, hh = _traj(choa_bt, "host", ADMM_SPECS, w_layout="bucketed")
    sm, hm = _traj(choa_bt, "mesh", ADMM_SPECS, check_every=4,
                   w_layout="bucketed")
    np.testing.assert_allclose(hm, hh, rtol=0, atol=1e-8)
    assert isinstance(sm.aux["w"], list) and len(sm.aux["w"]) == 2


def test_smooth_engine_parity(choa_bt):
    specs = {"v": "nonneg", "w": "smooth:0.2"}
    _, hh = _traj(choa_bt, "host", specs)
    _, hs = _traj(choa_bt, "scan", specs, check_every=4)
    _, hm = _traj(choa_bt, "mesh", specs, check_every=4)
    np.testing.assert_allclose(hs, hh, rtol=0, atol=1e-12)
    np.testing.assert_allclose(hm, hh, rtol=0, atol=1e-8)


# ---------------------------------------------------------------------------
# legacy nonneg flag: deprecation shim + default-path equivalence
# ---------------------------------------------------------------------------

def test_legacy_nonneg_flag_removed_with_migration_hint(choa_bt):
    """The PR-4 deprecation shim is gone: passing the old bool raises
    TypeError naming the constraints= replacement, and the explicit spec
    dict walks the SAME trajectory as the unset (paper-default) path —
    the bitwise guarantee the shim used to provide now holds between the
    default and its spelled-out form."""
    for legacy in (True, False):
        with pytest.raises(TypeError, match="constraints="):
            Parafac2Options(rank=3, nonneg=legacy, dtype=f64)
    with pytest.raises(TypeError, match="removed"):
        Parafac2Options(rank=3, nonneg=True, constraints={"v": "none"})
    new = Parafac2Options(rank=3, constraints={"v": "nonneg", "w": "nonneg"},
                          dtype=f64)
    default = Parafac2Options(rank=3, dtype=f64)      # unset -> paper default
    assert default.constraint_specs() == {"v": "nonneg", "w": "nonneg"}
    _, hn = fit(choa_bt, new, max_iters=8, tol=0.0, seed=0)
    _, hd = fit(choa_bt, default, max_iters=8, tol=0.0, seed=0)
    np.testing.assert_allclose(np.asarray(hd), np.asarray(hn), rtol=0, atol=0)


def test_default_path_aux_is_empty(choa_bt):
    """constraints unset -> hals/ridge routes only: no aux leaves anywhere
    (nothing extra in the engine carries)."""
    opts = Parafac2Options(rank=3, dtype=f64)
    s0 = init_state(choa_bt, opts, seed=0)
    assert jax.tree_util.tree_leaves(s0.aux) == []
    s1 = als_step(choa_bt, s0, opts)
    assert jax.tree_util.tree_leaves(s1.aux) == []


def test_constraints_for_validates_and_caches():
    opts = Parafac2Options(rank=3, constraints={"v": "nonneg+l1:0.1"})
    cons = constraints_for(opts)
    assert set(cons) == {"h", "v", "w"}
    assert cons["h"].solver == "ridge" and cons["w"].solver == "ridge"
    assert cons["v"].admm and cons["v"].nonneg


# ---------------------------------------------------------------------------
# baseline parity under constraints (apples-to-apples comparisons)
# ---------------------------------------------------------------------------

def test_baseline_step_matches_spartan_step_under_admm(exact_bt):
    from repro.core.baseline import baseline_als_step

    opts = Parafac2Options(rank=4, constraints=ADMM_SPECS, dtype=f64)
    s0 = init_state(exact_bt, opts, seed=0)
    sa = als_step(exact_bt, s0, opts)
    sb = baseline_als_step(exact_bt, s0, opts)
    np.testing.assert_allclose(np.asarray(sa.H), np.asarray(sb.H), atol=1e-9)
    np.testing.assert_allclose(np.asarray(sa.V), np.asarray(sb.V), atol=1e-9)
    np.testing.assert_allclose(np.asarray(sa.W), np.asarray(sb.W), atol=1e-9)
    for la, lb in zip(jax.tree_util.tree_leaves(sa.aux),
                      jax.tree_util.tree_leaves(sb.aux)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-9)


# ---------------------------------------------------------------------------
# interpretation consults the fitted spec
# ---------------------------------------------------------------------------

def test_temporal_signature_consults_constraint_spec():
    from repro.core.interpret import model_is_nonneg, temporal_signature

    Uk = np.asarray([[1.0, -2.0], [-0.5, 3.0]])
    nn_opts = Parafac2Options(rank=2, constraints={"v": "nonneg", "w": "nonneg"})
    un_opts = Parafac2Options(rank=2, constraints={"v": "none", "w": "none"})
    l1_opts = Parafac2Options(rank=2, constraints={"v": "l1:0.1", "w": "none"})
    assert model_is_nonneg(nn_opts) and not model_is_nonneg(un_opts)
    assert not model_is_nonneg(l1_opts)
    # nonneg fit: clipped, as in the paper
    clipped = temporal_signature(Uk, [0, 1], constraints=nn_opts)
    assert (clipped[1] >= 0).all() and clipped[1][0] == 0.0
    # unconstrained / l1-only fit: negative lobes preserved (no silent clip)
    for o in (un_opts, l1_opts):
        raw = temporal_signature(Uk, [0, 1], constraints=o)
        np.testing.assert_array_equal(raw[1], Uk[:, 1])
    # explicit override still wins
    forced = temporal_signature(Uk, [1], clip_nonneg=True, constraints=un_opts)
    assert (forced[1] >= 0).all()
