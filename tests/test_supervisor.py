"""Fault-tolerant supervisor correctness (ISSUE-10).

The contract under test: a fit with injected faults — a transient blip
(in-place retry), a retry-exhausting fault (checkpoint-restore + rewind),
and an injected NaN (health-sentinel rollback) — converges to the SAME
final factors as an unfaulted run: bitwise under the scan engine (the chunk
closes over the data, so the carried state is the only state), ≤1e-8 under
mesh. Plus the satellites: StepWatchdog behaviour under the supervisor
(straggler flags never consume the retry budget, compile chunks never
flagged, window bounding on 1000+ chunk histories), the nnz-balanced shard
planner, retry backoff/jitter determinism, resume, and the driver's
``--fail-at``/``--nan-at``/``--ckpt-dir`` surface with the
retry/restore/rollback counts stamped into the ``--json`` summary.

Slow-marked (nightly): the 4-process sharded-SCOO mesh fit vs
single-process (f64, ≤1e-8), kill-and-resume across two processes, and the
100M+-nnz SCOO geometry lowered on a 256-chip pod mesh with per-device
bytes under the 16 GiB budget.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import Parafac2Options, bucketize, fit
from repro.dist.fault import (FaultInjector, StepWatchdog, TransientFault,
                              run_with_retries)
from repro.dist.supervisor import SupervisorConfig, supervised_fit
from repro.sparse import plan_buckets, random_parafac2
from repro.sparse.bucketing import fixed_plan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RANK = 3
ITERS = 14   # 3 full chunks of 4 + a remainder chunk of 2


def _bt(seed=0, dtype=jnp.float64, subject_align=1):
    data, _ = random_parafac2(n_subjects=10, n_cols=30, max_rows=20,
                              rank=RANK, density=0.6, seed=seed, noise=0.05)
    return bucketize(data, max_buckets=2, dtype=dtype,
                     subject_align=subject_align)


def _opts(**kw):
    kw.setdefault("rank", RANK)
    kw.setdefault("dtype", jnp.float64)
    kw.setdefault("engine", "scan")
    kw.setdefault("check_every", 4)
    return Parafac2Options(**kw)


def _assert_state_equal(a, b, atol=0.0):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if atol:
            np.testing.assert_allclose(x, y, atol=atol, rtol=0)
        else:
            np.testing.assert_array_equal(x, y)


@pytest.fixture(scope="module")
def reference():
    """The unfaulted scan run every recovery path must reproduce bitwise."""
    bt = _bt()
    state, hist = fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0)
    return bt, state, hist


# ---------------------------------------------------------------------------
# supervised_fit: the recovery ladder, bitwise vs the unfaulted run
# ---------------------------------------------------------------------------

def test_faultless_supervised_bitwise(reference):
    bt, state, hist = reference
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0)
    assert h == hist
    _assert_state_equal(s, state)
    assert (rep.retries, rep.restores, rep.rollbacks) == (0, 0, 0)
    assert rep.chunks == 4


def test_transient_blip_retries_in_place_bitwise(reference):
    bt, state, hist = reference
    cfg = SupervisorConfig(injector=FaultInjector({1: 1}))
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert h == hist
    _assert_state_equal(s, state)
    assert rep.retries == 1 and rep.restores == 0 and rep.rollbacks == 0


def test_exhausted_retries_restore_from_ckpt_bitwise(reference, tmp_path):
    bt, state, hist = reference
    # times = max_retries + 1 exhausts exactly one run_with_retries pass,
    # then the fault clears: one checkpoint-restore, replay is clean
    cfg = SupervisorConfig(injector=FaultInjector({2: 3}), max_retries=2,
                           ckpt_dir=str(tmp_path))
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert h == hist
    _assert_state_equal(s, state)
    assert rep.restores == 1 and rep.retries == 2
    assert rep.checkpoints_written >= 4


def test_exhausted_retries_without_ckpt_dir_uses_memory_boundary(reference):
    bt, state, hist = reference
    cfg = SupervisorConfig(injector=FaultInjector({1: 3}), max_retries=2)
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert h == hist
    _assert_state_equal(s, state)
    assert rep.restores == 1


def test_nan_poison_rolls_back_bitwise(reference):
    bt, state, hist = reference
    cfg = SupervisorConfig(injector=FaultInjector(nan_steps=[1]))
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert h == hist and np.isfinite(h).all()
    _assert_state_equal(s, state)
    assert rep.rollbacks == 1 and rep.ridge_final == 0.0


def test_persistent_nan_escalates_ridge_and_recovers(reference):
    bt, state, hist = reference
    # the poison survives the first clean replay (times=2), so the sentinel
    # escalates to the tightened-regularization retry; the ridged trajectory
    # is finite and lands within soft tolerance of the unfaulted fit
    cfg = SupervisorConfig(injector=FaultInjector(nan_steps={1: 2}),
                           health_retries=1)
    s, h, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert rep.rollbacks == 2 and rep.escalations == 1
    assert rep.ridge_final > 0.0
    assert np.isfinite(h).all()
    assert abs(h[-1] - hist[-1]) < 1e-6


def test_unrecoverable_divergence_raises():
    bt = _bt()
    cfg = SupervisorConfig(injector=FaultInjector(nan_steps={0: 99}),
                           health_retries=0, max_escalations=2)
    with pytest.raises(RuntimeError, match="escalation"):
        supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                       config=cfg)


def test_supervisor_rejects_host_and_while_engines():
    bt = _bt()
    with pytest.raises(ValueError, match="scan"):
        supervised_fit(bt, _opts(engine="host"), max_iters=4)
    with pytest.raises(ValueError, match="chunk"):
        supervised_fit(bt, _opts(check_every=0), max_iters=4)


def test_mesh_engine_faulted_matches_unfaulted(reference):
    """The acceptance bound under mesh: faulted vs unfaulted ≤1e-8 (here on
    the default single-device mesh; the 4-process variant is slow-marked)."""
    bt = _bt(subject_align=len(jax.devices()))
    opts = _opts(engine="mesh")
    s0, h0 = fit(bt, opts, max_iters=ITERS, tol=0.0, seed=0)
    cfg = SupervisorConfig(
        injector=FaultInjector({1: 1, 2: 4}, nan_steps=[3]), max_retries=3)
    s1, h1, rep = supervised_fit(bt, opts, max_iters=ITERS, tol=0.0, seed=0,
                                 config=cfg)
    assert rep.retries >= 1 and rep.restores == 1 and rep.rollbacks == 1
    np.testing.assert_allclose(h0, h1, atol=1e-8, rtol=0)
    _assert_state_equal(s0, s1, atol=1e-8)


# ---------------------------------------------------------------------------
# resume (write-on-N, resume-on-M semantics live in test_ckpt/test_sharding)
# ---------------------------------------------------------------------------

def test_resume_continues_bitwise(reference, tmp_path):
    bt, state, hist = reference
    opts = _opts()
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path))
    supervised_fit(bt, opts, max_iters=8, tol=0.0, seed=0, config=cfg)
    cfg2 = SupervisorConfig(ckpt_dir=str(tmp_path), resume=True)
    s, h, rep = supervised_fit(bt, opts, max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg2)
    assert rep.resumed_from_step == 8
    assert h == hist
    _assert_state_equal(s, state)


def test_resume_without_ckpt_dir_raises():
    bt = _bt()
    with pytest.raises(ValueError, match="ckpt_dir"):
        supervised_fit(bt, _opts(), max_iters=4,
                       config=SupervisorConfig(resume=True))


# ---------------------------------------------------------------------------
# retry backoff/jitter (satellite: dist/fault.py)
# ---------------------------------------------------------------------------

def test_run_with_retries_backoff_jitter_deterministic():
    calls = {"n": 0}
    sleeps = []

    def flaky(tag, *, bump=1):
        calls["n"] += bump
        if calls["n"] < 4:
            raise TransientFault(tag)
        return tag

    out = run_with_retries(flaky, "ok", bump=1, max_retries=3,
                           backoff=0.5, backoff_factor=2.0, jitter=0.1,
                           seed=7, sleep=sleeps.append)
    assert out == "ok" and len(sleeps) == 3      # kwargs passed through
    # exponential base schedule, jitter multiplies by [1, 1.1)
    for i, s in enumerate(sleeps):
        base = 0.5 * 2.0 ** i
        assert base <= s < base * 1.1
    # same seed -> identical schedule (deterministic, private RNG stream)
    calls["n"] = 0
    sleeps2 = []
    run_with_retries(flaky, "ok", max_retries=3, backoff=0.5, jitter=0.1,
                     seed=7, sleep=sleeps2.append)
    assert sleeps == sleeps2


def test_run_with_retries_bare_call_still_works():
    def boom():
        raise TransientFault("always")

    with pytest.raises(TransientFault):
        run_with_retries(boom, max_retries=1)
    assert run_with_retries(lambda x: x + 1, 41) == 42


def test_fault_injector_per_step_times_and_poison():
    inj = FaultInjector({1: 2, 3: 1}, nan_steps={2: 2})
    for _ in range(2):
        with pytest.raises(TransientFault):
            inj.check(1)
    inj.check(1)                       # cleared after 2 firings
    assert inj.poison(2) and inj.poison(2) and not inj.poison(2)
    assert not inj.poison(1)           # only listed steps poison


# ---------------------------------------------------------------------------
# StepWatchdog under the supervisor (satellite)
# ---------------------------------------------------------------------------

def _clock_from(durations):
    """A fake SupervisorConfig.clock: dispatch i reads (t0=0, t1=dt_i)."""
    ticks = iter(t for dt in durations for t in (0.0, dt))
    return lambda: next(ticks)


def test_straggler_flagged_but_no_retry_budget_spent(reference):
    bt, state, hist = reference
    # 4 chunks; chunk 0 is the compile dispatch (never observed), chunk 3
    # compiles the remainder length (never observed) -> observed: 1, 2. Add
    # iterations so a slow chunk lands after min_history warm-up.
    opts = _opts(check_every=2)
    s0, h0 = fit(bt, opts, max_iters=12, tol=0.0, seed=0)
    durations = [9.9, 1.0, 1.0, 1.0, 1.0, 50.0]   # chunk 5 straggles
    cfg = SupervisorConfig(clock=_clock_from(durations))
    s, h, rep = supervised_fit(bt, opts, max_iters=12, tol=0.0, seed=0,
                               config=cfg)
    assert rep.stragglers == [5]
    assert rep.retries == 0 and rep.restores == 0 and rep.rollbacks == 0
    assert h == h0                       # a slow chunk is still committed
    _assert_state_equal(s, s0)


def test_cold_start_compile_chunk_never_flagged(reference):
    bt, _, _ = reference
    # chunk 0 (compile) is excluded by construction; the first OBSERVED
    # chunks are under min_history and never flag either, however slow
    durations = [999.0, 500.0, 1.0, 1.0]
    cfg = SupervisorConfig(clock=_clock_from(durations))
    _, _, rep = supervised_fit(bt, _opts(), max_iters=ITERS, tol=0.0, seed=0,
                               config=cfg)
    assert rep.stragglers == []


def test_watchdog_window_bounds_1000_plus_chunk_histories():
    wd = StepWatchdog(factor=3.0, min_history=3, window=50)
    for step in range(1200):
        assert not wd.observe(step, 1.0)
    assert len(wd._times) <= wd.window       # bounded, not 1200
    assert wd.observe(1200, 10.0)            # flagged vs the median
    assert wd.flagged == [1200]
    assert len(wd._times) <= wd.window       # flagged dt excluded from history


# ---------------------------------------------------------------------------
# nnz-balanced shard planner (tentpole layer 1, sparse/bucketing.py)
# ---------------------------------------------------------------------------

def test_balance_for_shards_equalizes_nnz():
    rng = np.random.default_rng(0)
    n, shards = 64, 4
    nnz = rng.integers(1, 1000, size=n)
    plan = fixed_plan(n, i_pad=8, c_pad=128)
    before = plan.shard_imbalance(nnz, shards)
    bal = plan.balance_for_shards(nnz, shards)
    after = bal.shard_imbalance(nnz, shards)
    # same members, same shapes — only the order moved
    assert sorted(np.concatenate(bal.members).tolist()) == list(range(n))
    assert bal.shapes == plan.shapes and bal.nnz_pads == plan.nnz_pads
    assert after <= before
    assert after < 1.05                      # LPT gets near-perfect here
    # per-shard loads match the imbalance accounting
    loads = bal.shard_nnz(nnz, shards)[0]
    assert sum(loads) == int(nnz.sum())


def test_balance_respects_tail_padding_capacities():
    # 10 members over 4 shards -> padded Kb 12, capacities [3, 3, 3, 1]:
    # the LAST shard holds the padding, so it must get the fewest subjects
    nnz = np.arange(1, 11) * 10
    plan = fixed_plan(10, i_pad=8, c_pad=128)
    bal = plan.balance_for_shards(nnz, 4)
    mem = bal.members[0]
    cs = -(-len(mem) // 4)
    sizes = [len(mem[s * cs:(s + 1) * cs]) for s in range(4)]
    assert sizes == [3, 3, 3, 1]
    assert bal.shard_imbalance(nnz, 4) <= plan.shard_imbalance(nnz, 4)


def test_balance_single_shard_is_identity_and_validates():
    plan = fixed_plan(6, i_pad=8, c_pad=128)
    assert plan.balance_for_shards([1] * 6, 1) is plan
    with pytest.raises(ValueError, match="n_shards"):
        plan.balance_for_shards([1] * 6, 0)


def test_balanced_plan_fit_matches_unbalanced(reference):
    """Reordering members changes slot assignment, not the model: the fits
    agree to reassociation-level fp noise (f64)."""
    bt, _, hist = reference
    data, _ = random_parafac2(n_subjects=10, n_cols=30, max_rows=20,
                              rank=RANK, density=0.6, seed=0, noise=0.05)
    plan = plan_buckets(data.row_counts(), data.col_counts(), max_buckets=2,
                        nnz_counts=data.nnz_counts())
    bal = plan.balance_for_shards(data.nnz_counts(), 2)
    bt2 = bucketize(data, dtype=jnp.float64, plan=bal, subject_align=2)
    _, h2 = fit(bt2, _opts(), max_iters=ITERS, tol=0.0, seed=0)
    np.testing.assert_allclose(h2, hist, atol=1e-10, rtol=0)


# ---------------------------------------------------------------------------
# driver surface: --fail-at / --nan-at / --ckpt-dir / --resume + --json
# ---------------------------------------------------------------------------

def test_decompose_faulted_run_bitwise_with_counts_in_json(tmp_path):
    """The acceptance command: choa rank-5/20-iter with a transient blip, a
    retry-exhausting fault (restore), and an injected NaN (rollback) —
    fit_history bitwise vs the unfaulted run, counts in the --json blob."""
    from repro.launch.decompose import main

    base = ["--dataset", "choa", "--scale", "0.001", "--rank", "5",
            "--iters", "20", "--engine", "scan", "--check-every", "5",
            "--tol", "0"]
    clean = main(base + ["--json", str(tmp_path / "clean.json")])
    faulted = main(base + [
        "--fail-at", "1,2:4", "--nan-at", "3", "--max-retries", "3",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--json", str(tmp_path / "faulted.json")])
    assert faulted["fit_history"] == clean["fit_history"]
    blob = json.loads((tmp_path / "faulted.json").read_text())
    sup = blob["supervisor"]
    assert sup["retries"] >= 1           # the blip at chunk 1
    assert sup["restores"] == 1          # chunk 2 exhausted its retries
    assert sup["rollbacks"] == 1         # the NaN at chunk 3
    assert sup["checkpoints_written"] >= 1
    assert blob["supervisor"]["ridge_final"] == 0.0
    assert json.loads((tmp_path / "clean.json").read_text())["supervisor"] is None


def test_decompose_ckpt_resume_bitwise(tmp_path):
    from repro.launch.decompose import main

    base = ["--dataset", "choa", "--scale", "0.001", "--rank", "5",
            "--engine", "scan", "--check-every", "5", "--tol", "0",
            "--ckpt-dir", str(tmp_path / "ckpt")]
    full = main(base + ["--iters", "20", "--json", str(tmp_path / "a.json")])
    # fresh dir: run 10, then resume to 20 — history must match the one-shot
    base2 = [a if a != str(tmp_path / "ckpt") else str(tmp_path / "ckpt2")
             for a in base]
    main(base2 + ["--iters", "10"])
    resumed = main(base2 + ["--iters", "20", "--resume",
                            "--json", str(tmp_path / "b.json")])
    assert resumed["supervisor"]["resumed_from_step"] == 10
    assert resumed["fit_history"] == full["fit_history"]


def test_decompose_fault_flags_reject_host_engine():
    from repro.launch.decompose import main

    with pytest.raises(SystemExit):
        main(["--dataset", "choa", "--scale", "0.001", "--engine", "host",
              "--fail-at", "1"])


def test_parse_fail_spec():
    from repro.launch.decompose import parse_fail_spec

    assert parse_fail_spec("") == {}
    assert parse_fail_spec("1,3:5") == {1: 1, 3: 5}
    with pytest.raises(ValueError, match="fault spec"):
        parse_fail_spec("x:y")


# ---------------------------------------------------------------------------
# slow suite: multi-process sharded SCOO, kill-and-resume, pod dryrun cell
# ---------------------------------------------------------------------------

def _run_sub(src, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(src)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.slow
def test_sharded_scoo_mesh_4proc_matches_single():
    """Tentpole layer 1: the SCOO buckets under shard_map on 4 forced host
    devices, members nnz-BALANCED across the shards; the mesh fit matches
    the single-device host fit ≤1e-8 in f64."""
    proc = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np, jax.numpy as jnp, dataclasses
        from repro.core import Parafac2Options, bucketize, fit
        from repro.sparse import plan_buckets, random_irregular

        data = random_irregular(n_subjects=48, n_cols=256, max_rows=40,
                                avg_nnz_per_subject=60, seed=0)
        nnz = data.nnz_counts()
        plan = plan_buckets(data.row_counts(), data.col_counts(),
                            max_buckets=2, nnz_counts=nnz, sort_by="nnz")
        bal = plan.balance_for_shards(nnz, 4)
        assert (bal.shard_imbalance(nnz, 4)
                <= plan.shard_imbalance(nnz, 4) + 1e-12)
        bt = bucketize(data, dtype=jnp.float64, plan=bal, subject_align=4,
                       formats=["scoo"] * bal.n_buckets)
        for b in bt.buckets:
            assert type(b).__name__ == "SparseBucket" and b.kb % 4 == 0

        opts = Parafac2Options(rank=3, dtype=jnp.float64, backend="auto",
                               engine="mesh", check_every=4)
        sm, hm = fit(bt, opts, max_iters=10, tol=0.0, seed=0)
        sh, hh = fit(bt, dataclasses.replace(opts, engine="host"),
                     max_iters=10, tol=0.0, seed=0)
        np.testing.assert_allclose(hm, hh, atol=1e-8, rtol=0)
        for a, b in zip(jax.tree_util.tree_leaves(sm),
                        jax.tree_util.tree_leaves(sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-8, rtol=0)
        print("SCOO4_OK", hm[-1])
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SCOO4_OK" in proc.stdout


@pytest.mark.slow
def test_kill_and_resume_across_processes(tmp_path):
    """The preemption story end-to-end: process A fits 8 iterations under
    the supervisor and dies; process B resumes from A's checkpoints and
    must land bitwise on the uninterrupted 16-iteration trajectory."""
    common = """
        import numpy as np, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.core import Parafac2Options, bucketize, fit
        from repro.dist.supervisor import SupervisorConfig, supervised_fit
        from repro.sparse import random_parafac2

        data, _ = random_parafac2(n_subjects=10, n_cols=30, max_rows=20,
                                  rank=3, density=0.6, seed=0, noise=0.05)
        bt = bucketize(data, max_buckets=2, dtype=jnp.float64)
        opts = Parafac2Options(rank=3, dtype=jnp.float64, engine="scan",
                               check_every=4)
    """
    a = _run_sub(common + f"""
        cfg = SupervisorConfig(ckpt_dir={str(tmp_path)!r})
        supervised_fit(bt, opts, max_iters=8, tol=0.0, seed=0, config=cfg)
        print("PHASE1_OK")
    """, timeout=300)
    assert a.returncode == 0, a.stderr[-2000:]
    assert "PHASE1_OK" in a.stdout
    b = _run_sub(common + f"""
        cfg = SupervisorConfig(ckpt_dir={str(tmp_path)!r}, resume=True)
        s, h, rep = supervised_fit(bt, opts, max_iters=16, tol=0.0, seed=0,
                                   config=cfg)
        assert rep.resumed_from_step == 8, rep
        s0, h0 = fit(bt, opts, max_iters=16, tol=0.0, seed=0)
        assert h == h0
        for x, y in zip(jax.tree_util.tree_leaves(s),
                        jax.tree_util.tree_leaves(s0)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("RESUME_OK")
    """, timeout=300)
    assert b.returncode == 0, b.stderr[-2000:]
    assert "RESUME_OK" in b.stdout


@pytest.mark.slow
def test_dryrun_pod_cell_100m_nnz_under_budget():
    """Tentpole layer 1's scale proof: the synth-500M SCOO geometry (592M
    padded triplets — well past 100M nnz) lowered through the mesh engine on
    the 256-chip pod mesh, per-device bytes under the 16 GiB HBM budget."""
    proc = _run_sub("""
        from repro.launch import dryrun as dr

        mesh = dr.make_production_mesh(multi_pod=False)
        rec = dr.run_parafac2_cell("parafac2-synth500m-r40", mesh, "pod16x16",
                                   engine="mesh", format="scoo",
                                   check_every=2)
        assert rec["n_chips"] == 256, rec["n_chips"]
        assert rec["padded_nnz"] >= 100_000_000, rec["padded_nnz"]
        assert rec["fits_hbm_16g"], rec["bytes_per_device"]
        print("POD_SCOO_OK", rec["padded_nnz"], rec["bytes_per_device"])
    """)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "POD_SCOO_OK" in proc.stdout
