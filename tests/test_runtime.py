"""Distributed-runtime substrate: checkpoint/restore (incl. elastic+atomic),
fault handling, optimizer, schedules, gradient compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import TokenStream
from repro.dist.fault import FaultInjector, StepWatchdog, TransientFault, run_with_retries
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    ef_compress_update,
    dequantize,
    quantize,
    wsd_schedule,
)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.standard_normal((4, 3))),
                   "b": jnp.asarray(rng.standard_normal((3,)))},
        "head": [jnp.asarray(rng.standard_normal((3, 5)))],
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"data": {"seed": 1, "step": 7}})
    restored, step, extra = ckpt.restore(str(tmp_path), t)
    assert step == 7 and extra["data"]["step"] == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b), t, restored)


def test_checkpoint_keeps_newest_and_prunes(tmp_path):
    t = _tree()
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [30, 40]
    assert ckpt.latest_step(str(tmp_path)) == 40


def test_checkpoint_ignores_corrupt_dir(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    os.makedirs(tmp_path / "step_000000009")  # no meta.json -> damaged
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_restores_dtype(tmp_path):
    t = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    ckpt.save(str(tmp_path), 1, t)
    restored, _, _ = ckpt.restore(str(tmp_path), t)
    assert restored["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_retry_recovers_from_transient():
    inj = FaultInjector(fail_steps=(3,))
    calls = []

    def step(s):
        inj.check(s)
        calls.append(s)
        return s * 2

    assert run_with_retries(step, 3) == 6
    assert calls == [3]  # failed once, then succeeded


def test_retry_exhausts():
    def always(_):
        raise TransientFault("boom")

    with pytest.raises(TransientFault):
        run_with_retries(always, 0, max_retries=2)


def test_watchdog_flags_straggler():
    wd = StepWatchdog(factor=2.0)
    for i in range(10):
        wd.observe(i, 1.0)
    assert wd.observe(10, 5.0) is True
    assert 10 in wd.flagged
    assert wd.observe(11, 1.1) is False


# ---------------------------------------------------------------------------
# optimizer + schedules
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, lr=0.05, wd=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_clip_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_wsd_schedule_shape():
    s = wsd_schedule(peak=1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(50)) == pytest.approx(1.0)
    assert float(s(99)) < 0.5


def test_cosine_schedule_monotone_tail():
    s = cosine_schedule(peak=1.0, warmup=5, total=50)
    vals = [float(s(i)) for i in range(5, 50, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_dequantize_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-7


def test_error_feedback_accumulates_lost_mass():
    """EF invariant: decoded + new_error == grad + old_error (lossless ledger)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    e = jnp.asarray(rng.standard_normal(256) * 0.01, jnp.float32)
    _, _, decoded, new_e = ef_compress_update(g, e)
    np.testing.assert_allclose(decoded + new_e, g + e, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_tokenstream_deterministic_and_resumable():
    a = TokenStream(vocab_size=100, batch=2, seq_len=8, seed=3)
    b = TokenStream(vocab_size=100, batch=2, seq_len=8, seed=3)
    b.restore(a.state())
    for step in (0, 1, 5, 1000):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    ba = a.batch_at(4)
    assert ba["tokens"].min() >= 1 and ba["tokens"].max() < 100
    assert (ba["labels"][:, -1] == -1).all()
    np.testing.assert_array_equal(ba["labels"][:, :-1], ba["tokens"][:, 1:])
