"""Checkpoint/resume correctness for the serving & training paths.

ISSUE-6 satellite: `checkpoint/ckpt.py` grew users (the streaming service
warm state, scan-engine resumes) whose correctness depends on properties the
basic round-trip tests in test_runtime.py never pinned down:

  * a full ``Parafac2State`` — including the PR-4 ``aux`` ADMM dual pytree
    (nested dict of tuples of arrays) — survives save/restore leaf-exact;
  * elastic reshard: a checkpoint written sharded over N devices restores
    onto an M-device submesh (the "write on 512, resume on 64" path, scaled
    to forced host devices in a subprocess — slow-marked);
  * restore-then-continue under the scan engine is BITWISE identical to the
    uninterrupted run (scan closes over the data, so the only state is the
    carried ``Parafac2State`` — if the checkpoint preserves it exactly, the
    trajectory must re-converge exactly).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.core import Parafac2Options, bucketize, fit, init_state
from repro.sparse import random_parafac2

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RANK = 3


def _bt(seed=0, dtype=jnp.float64):
    data, _ = random_parafac2(n_subjects=10, n_cols=30, max_rows=20,
                              rank=RANK, density=0.6, seed=seed, noise=0.05)
    return bucketize(data, max_buckets=2, dtype=dtype)


def _admm_opts(**kw):
    """Options whose W constraint routes through ADMM, so ``state.aux``
    carries a real (Z, U) dual pytree (the PR-4 structure)."""
    kw.setdefault("rank", RANK)
    kw.setdefault("dtype", jnp.float64)
    kw.setdefault("constraints", {"v": "nonneg", "w": "nonneg+l1:0.01"})
    return Parafac2Options(**kw)


def _assert_state_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_parafac2_state_roundtrip_with_admm_aux(tmp_path):
    bt = _bt()
    opts = _admm_opts()
    state, _ = fit(bt, opts, max_iters=5, tol=0.0, seed=0)
    # the aux pytree must actually contain ADMM duals, otherwise this test
    # is vacuous
    aux_leaves = jax.tree_util.tree_leaves(state.aux)
    assert len(aux_leaves) >= 2, "expected (Z, U) duals in state.aux"

    ckpt.save(str(tmp_path), 5, state, extra={"fit": float(state.fit)})
    template = init_state(bt, opts, seed=0)  # same structure, fresh values
    restored, step, extra = ckpt.restore(str(tmp_path), template)
    assert step == 5
    assert extra["fit"] == float(state.fit)
    _assert_state_equal(restored, state)


def test_restore_then_continue_bitwise_scan(tmp_path):
    """Interrupt/resume under the scan engine reproduces the uninterrupted
    trajectory BITWISE: same chunk boundaries, state round-tripped exactly
    through disk, data closed over by the compiled chunk."""
    bt = _bt(seed=1)
    opts = _admm_opts(engine="scan", check_every=4)

    # uninterrupted: 16 iterations in 4-iteration scan chunks
    full, _ = fit(bt, opts, max_iters=16, tol=0.0, seed=0)

    # interrupted at the 8-iteration chunk boundary + resumed from disk
    half, _ = fit(bt, opts, max_iters=8, tol=0.0, seed=0)
    ckpt.save(str(tmp_path), 8, half)
    template = init_state(bt, opts, seed=0)
    restored, _, _ = ckpt.restore(str(tmp_path), template)
    _assert_state_equal(restored, half)
    resumed, _ = fit(bt, opts, max_iters=8, tol=0.0, seed=0, state=restored)

    _assert_state_equal(resumed, full)


def test_restore_casts_to_template_dtype(tmp_path):
    t = {"a": jnp.arange(6, dtype=jnp.float64).reshape(2, 3)}
    ckpt.save(str(tmp_path), 1, t)
    restored, _, _ = ckpt.restore(
        str(tmp_path), {"a": jnp.zeros((2, 3), jnp.float32)})
    assert restored["a"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(6, dtype=np.float32).reshape(2, 3))


def test_restore_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError, match="b"):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(3)})


@pytest.mark.slow
def test_elastic_reshard_write_8_restore_4_subprocess():
    """The 'write on 512 chips, resume on 64' path, scaled down: save a
    state sharded over an 8-device mesh, restore it onto a 4-device submesh
    via the ``shardings=`` argument — values identical, new placement."""
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        jax.config.update("jax_enable_x64", True)
        from repro import checkpoint as ckpt

        assert len(jax.devices()) == 8
        mesh8 = Mesh(np.asarray(jax.devices()), ("s",))
        sh8 = NamedSharding(mesh8, P("s"))
        tree = {"W": jax.device_put(
                    jnp.arange(16 * 3, dtype=jnp.float64).reshape(16, 3),
                    sh8),
                "H": jnp.eye(3, dtype=jnp.float64)}
        assert len(tree["W"].sharding.device_set) == 8

        d = tempfile.mkdtemp()
        ckpt.save(d, 512, tree)

        mesh4 = Mesh(np.asarray(jax.devices()[:4]), ("s",))
        sh4 = NamedSharding(mesh4, P("s"))
        template = {"W": jnp.zeros((16, 3), jnp.float64),
                    "H": jnp.zeros((3, 3), jnp.float64)}
        shards = {"W": sh4, "H": NamedSharding(mesh4, P())}
        restored, step, _ = ckpt.restore(d, template, shardings=shards)
        assert step == 512
        assert len(restored["W"].sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(restored["W"]),
                                      np.asarray(tree["W"]))
        np.testing.assert_array_equal(np.asarray(restored["H"]), np.eye(3))
        print("RESHARD_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESHARD_OK" in proc.stdout


def test_stream_service_state_roundtrip(tmp_path):
    """The streaming service's warm-state checkpoint (launch/stream.py)
    restores leaf-exact, including the residual ledger and the sticky batch
    geometry recorded in ``extra``."""
    from repro.launch.stream import StreamService, synthetic_stream

    data, _ = random_parafac2(n_subjects=10, n_cols=30, max_rows=20,
                              rank=RANK, density=0.6, seed=2, noise=0.05)
    opts = Parafac2Options(rank=RANK, dtype=jnp.float64)
    warm, payloads = synthetic_stream(data, warm_frac=0.6, seed=2)
    svc, _ = StreamService.warm_start(warm, opts, iters=5, seed=0,
                                      batch_slots=2, drift_threshold=np.inf)
    for p in payloads:
        svc.submit(p)
    svc.flush()
    svc.save(str(tmp_path))

    svc2 = StreamService.from_checkpoint(str(tmp_path), svc.union_data(),
                                         opts, batch_slots=2,
                                         drift_threshold=np.inf)
    np.testing.assert_array_equal(svc2.W, svc.W)
    np.testing.assert_array_equal(np.asarray(svc2.H), np.asarray(svc.H))
    np.testing.assert_array_equal(np.asarray(svc2.V), np.asarray(svc.V))
    np.testing.assert_array_equal(svc2._sub_resid, svc._sub_resid)
    np.testing.assert_array_equal(svc2._sub_norm, svc._sub_norm)
    assert svc2.baseline_fit == svc.baseline_fit
    assert svc2.n_appends == svc.n_appends
    assert (svc2._i_pad, svc2._c_pad, svc2._n_pad) == (
        svc._i_pad, svc._c_pad, svc._n_pad)
    # subject-count mismatch between checkpoint and dataset fails fast
    with pytest.raises(ValueError, match="subjects"):
        StreamService.from_checkpoint(
            str(tmp_path),
            type(data)(subjects=list(data.subjects[:-1]),
                       n_cols=data.n_cols),
            opts)
