"""repro.dist.sharding unit coverage: rule resolution, the context stack,
no-op behavior outside a mesh, param_shardings on a small pytree, and the
fault-free helpers (barrier, unroll switch). Single-device, fast."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    LM_RULES, SP_RULES, axis_rules, barrier, current_mesh, current_rules,
    enforce_divisible, logical_spec, param_shardings, param_spec, shard,
    unroll_active, unroll_loops)


# ---------------------------------------------------------------------------
# axis-rule resolution
# ---------------------------------------------------------------------------

def test_rules_resolve_known_logical_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with axis_rules(LM_RULES, mesh):
        assert logical_spec(("batch", "seq", "mlp")) == P("data", None, "model")
        assert logical_spec(("tokens", "embed")) == P("data", None)
        # subjects are subject-wide: every mesh axis
        assert logical_spec(("subjects", None)) == P(("data", "model"), None)
        # unknown logical names replicate
        assert logical_spec(("no_such_axis",)) == P(None)
        # explicit None entries replicate
        assert logical_spec((None, "heads")) == P(None, "model")


def test_rules_drop_axes_missing_from_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    with axis_rules(LM_RULES, mesh):
        # "pod" and "model" don't exist on a 1-axis mesh
        assert logical_spec(("batch", "heads")) == P("data", None)


def test_sp_rules_shard_residual_seq():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with axis_rules(SP_RULES, mesh):
        assert logical_spec(("batch", "seq_res", "embed")) == P(
            "data", "model", None)
    with axis_rules(LM_RULES, mesh):
        assert logical_spec(("batch", "seq_res", "embed")) == P(
            "data", None, None)


def test_context_stack_nests_and_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert current_rules() is None and current_mesh() is None
    with axis_rules(LM_RULES, mesh):
        assert current_rules() is LM_RULES and current_mesh() is mesh
        with axis_rules(SP_RULES, None):
            assert current_rules() is SP_RULES and current_mesh() is None
        assert current_rules() is LM_RULES and current_mesh() is mesh
    assert current_rules() is None and current_mesh() is None


# ---------------------------------------------------------------------------
# shard: no-op outside a mesh, constraint inside
# ---------------------------------------------------------------------------

def test_shard_is_noop_outside_mesh():
    x = jnp.arange(12.0).reshape(3, 4)
    assert shard(x, ("batch", "embed")) is x            # no context at all
    with axis_rules(LM_RULES, None):                    # rules but no mesh
        assert shard(x, ("batch", "embed")) is x


def test_shard_applies_constraint_under_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(8.0).reshape(2, 4)
    with axis_rules(LM_RULES, mesh):
        y = shard(x, ("batch", "mlp"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert y.sharding.is_equivalent_to(
        NamedSharding(mesh, P("data", "model")), 2)


class _MeshShape:
    """Stand-in with the two attributes divisibility checks read (the test
    process owns a single real device, so no true multi-device mesh)."""

    def __init__(self, shape, names):
        self.devices = np.zeros(shape)
        self.axis_names = names


def test_enforce_divisible_keeps_exact_and_drops_uneven():
    mesh = _MeshShape((2,), ("data",))
    assert enforce_divisible(P("data"), (8,), mesh) == P("data")
    assert enforce_divisible(P("data"), (7,), mesh) == P(None)
    # short specs are padded with None up to the array rank
    assert enforce_divisible(P("data"), (8, 3), mesh) == P("data", None)
    # multi-axis entries drop only when the combined size doesn't divide
    mesh2 = _MeshShape((2, 2), ("data", "model"))
    assert enforce_divisible(P(("data", "model")), (8,), mesh2) == P(
        ("data", "model"))
    assert enforce_divisible(P(("data", "model")), (6,), mesh2) == P(None)


# ---------------------------------------------------------------------------
# param_shardings on a small pytree
# ---------------------------------------------------------------------------

def test_param_shardings_small_pytree():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {
        "embed": {"tokens": jax.ShapeDtypeStruct((8, 4), jnp.float32)},
        "layers": {"groups": {"p0_attn_mlp": {
            "attn": {"wq": jax.ShapeDtypeStruct((3, 4, 4), jnp.float32)},
            "ln1_scale": jax.ShapeDtypeStruct((3, 4), jnp.float32),
            "mlp": {"w_down": jax.ShapeDtypeStruct((3, 4, 4), jnp.float32)},
        }}},
        "final_norm_scale": jax.ShapeDtypeStruct((4,), jnp.float32),
    }
    sh = param_shardings(tree, mesh)
    assert sh["embed"]["tokens"].spec == P("model", "data")
    grp = sh["layers"]["groups"]["p0_attn_mlp"]
    # stacked leading layer dim never sharded
    assert grp["attn"]["wq"].spec == P(None, "data", "model")
    assert grp["mlp"]["w_down"].spec == P(None, "model", "data")
    assert grp["ln1_scale"].spec == P()
    assert sh["final_norm_scale"].spec == P()
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree_util.tree_leaves(
                   sh, is_leaf=lambda x: isinstance(x, NamedSharding)))


def test_param_spec_respects_divisibility():
    mesh = _MeshShape((2, 2), ("data", "model"))
    # 7 not divisible by data=2 -> replicated; 6 divisible by model=2 -> kept
    assert enforce_divisible(param_spec("attn/wq", 2), (7, 6), mesh) == P(
        None, "model")


def test_param_spec_optimizer_state_matches_params():
    for prefix in ("", "m/", "v/", "1/"):
        assert param_spec(prefix + "layers/rem/0/attn/wo", 2) == P(
            "model", "data")
    assert param_spec("experts/w_gate", 3) == P("model", None, None)
    assert param_spec("m/experts/w_gate", 4, stacked=True) == P(
        None, "model", None, None)


# ---------------------------------------------------------------------------
# barrier + unroll switch
# ---------------------------------------------------------------------------

def test_barrier_identity_and_differentiable():
    x = jnp.arange(4.0)
    np.testing.assert_array_equal(np.asarray(barrier(x)), np.asarray(x))
    g = jax.grad(lambda a: (barrier(a) ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 2.0 * np.asarray(x))


def test_unroll_loops_nesting():
    assert not unroll_active()
    with unroll_loops():
        assert unroll_active()
        with unroll_loops():
            assert unroll_active()
        assert unroll_active()
    assert not unroll_active()
