"""Quickstart: fit a PARAFAC2 model to a synthetic irregular tensor and
recover its planted structure.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.sparse import random_parafac2
from repro.core import Parafac2Options, bucketize, fit, reconstruct_uk


def main():
    # 1) make an irregular dataset from a planted rank-4 PARAFAC2 model
    data, truth = random_parafac2(
        n_subjects=50, n_cols=60, max_rows=40, rank=4, density=0.8, seed=7)
    print(f"K={data.n_subjects} subjects, J={data.n_cols} variables, "
          f"nnz={data.nnz}")

    # 2) pack ragged subjects into static-shape buckets (the TPU-native CC format)
    bucketed = bucketize(data, max_buckets=3)

    # 3) fit
    opts = Parafac2Options(rank=4, constraints={"v": "nonneg", "w": "nonneg"})
    state, history = fit(bucketed, opts, max_iters=60, tol=1e-7, verbose=False)
    print(f"fit after {len(history)} iterations: {history[-1]:.4f}")
    assert history[-1] > 0.5

    # 4) inspect the factors
    print("V (variable loadings) shape:", np.asarray(state.V).shape)
    print("W (subject importances) shape:", np.asarray(state.W).shape)
    uks = reconstruct_uk(bucketed, state, opts)
    print("U_0 (temporal signature of subject 0) shape:", uks[0].shape)
    print("PARAFAC2 invariant: U_k^T U_k constant across subjects ->",
          np.allclose(uks[0].T @ uks[0], uks[1].T @ uks[1], atol=1e-2))


if __name__ == "__main__":
    main()
