"""Temporal phenotyping on synthetic EHR data — the paper's §5.3 case study.

Fits a rank-5 non-negative PARAFAC2 model to CHOA-shaped synthetic records,
prints the phenotype definitions (V), each subject's top phenotypes (S_k) and
a temporal signature (U_k), mirroring Figure 8 / Table 4 of the paper.

  PYTHONPATH=src python examples/phenotyping.py
"""
import numpy as np

from repro.core import Parafac2Options, bucketize, fit, reconstruct_uk
from repro.core.interpret import (
    subject_top_phenotypes,
    temporal_signature,
    top_phenotype_features,
)
from repro.data import choa_like

FEATURES = [f"dx:ccs_{i}" for i in range(800)] + [f"rx:cat_{i}" for i in range(528)]


def main():
    data = choa_like(scale=0.001, seed=3, with_phenotypes=True, rank=5)
    print(f"synthetic MCP cohort: K={data.n_subjects}, J={data.n_cols}, "
          f"nnz={data.nnz}")
    bucketed = bucketize(data, max_buckets=4)
    opts = Parafac2Options(rank=5, constraints={"v": "nonneg", "w": "nonneg"})
    state, hist = fit(bucketed, opts, max_iters=40, tol=1e-6)
    print(f"fit: {hist[-1]:.4f} ({len(hist)} iters)\n")

    print("== phenotype definitions (top features of V) ==")
    for r, feats in enumerate(top_phenotype_features(
            np.asarray(state.V), FEATURES, top=6)):
        pretty = ", ".join(f"{n} ({w:.2f})" for n, w in feats)
        print(f"  phenotype {r}: {pretty}")

    W = np.asarray(state.W)
    uks = reconstruct_uk(bucketed, state, opts)
    for k in (0, 1):
        tops = subject_top_phenotypes(W, k, top=2)
        print(f"\n== subject {k}: top phenotypes {tops} ==")
        sig = temporal_signature(uks[k], [r for r, _ in tops], constraints=opts)
        for r, series in sig.items():
            spark = "".join(" .:-=+*#"[min(7, int(v / (series.max() + 1e-9) * 7))]
                            for v in series[:60])
            print(f"  phenotype {r} over {len(series)} weeks: |{spark}|")


if __name__ == "__main__":
    main()
