"""PARAFAC2 over LM activations — the paper's technique applied to the
assigned-architecture world (DESIGN.md §Arch-applicability).

K sequences of *unequal* length I_k, each producing hidden states of width
J = d_model, form exactly the irregular tensor PARAFAC2 models: we train a
tiny qwen3-family LM briefly, harvest per-sequence activation matrices,
sparsify (top-magnitude entries, like recorded medical events), and extract
per-sequence temporal signatures U_k and shared "activation phenotypes" V.

  PYTHONPATH=src python examples/lm_activation_signatures.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import Parafac2Options, bucketize, fit, reconstruct_uk
from repro.data import TokenStream
from repro.models import build
from repro.models.transformer import lm_forward
from repro.sparse import from_dense_slices


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    bundle = build(cfg, lr=3e-3, total_steps=60)
    rng = jax.random.PRNGKey(0)
    params = bundle.init_params(rng)
    opt = bundle.init_opt(params)
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=4, seq_len=32, seed=1)
    step = jax.jit(bundle.train_step)
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        params, opt, m = step(params, opt, batch, i)
    print(f"tiny LM trained 40 steps, loss={float(m['loss']):.3f}")

    # harvest final-layer hidden states for sequences of UNEQUAL length
    lengths = [9, 14, 20, 27, 32, 12, 24, 30]
    slices = []
    for k, L in enumerate(lengths):
        toks = jnp.asarray(stream.batch_at(100 + k)["tokens"][:1, :L])
        logits, _ = lm_forward(params, toks, cfg)
        # use pre-head logits' top activations as "events" (sparse, nonneg)
        h = np.asarray(logits[0].astype(jnp.float32))[:, :64]
        h = np.maximum(h - np.quantile(h, 0.6, axis=1, keepdims=True), 0.0)
        slices.append(h)             # first 64 vocab dims as variables
    data = from_dense_slices(slices)
    print(f"irregular activation tensor: K={data.n_subjects} sequences, "
          f"J={data.n_cols}, ragged I_k={lengths}, nnz={data.nnz}")

    bucketed = bucketize(data, max_buckets=2)
    opts = Parafac2Options(rank=3, constraints={"v": "nonneg", "w": "nonneg"})
    state, hist = fit(bucketed, opts, max_iters=40, tol=1e-6)
    print(f"PARAFAC2 fit on activations: {hist[-1]:.4f}")

    uks = reconstruct_uk(bucketed, state, opts)
    for k in (0, 1):
        sig = np.maximum(uks[k][:, 0], 0)
        spark = "".join(" .:-=+*#"[min(7, int(v / (sig.max() + 1e-9) * 7))]
                        for v in sig)
        print(f"sequence {k} (len {lengths[k]}) signature[phenotype 0]: |{spark}|")
    print("shared activation phenotypes V:", np.asarray(state.V).shape)


if __name__ == "__main__":
    main()
